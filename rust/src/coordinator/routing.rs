//! Pure routing decisions, separated from the threads that act on them so
//! every policy is unit-testable without artifacts or workers:
//!
//! * **bucket selection** — which (T, B) bucket of a hidden dim serves a
//!   sequence (smallest fitting T, widest B at equal T, mirrored by
//!   `Manifest::pick_seq` so batched and unbatched paths bind the same
//!   artifact);
//! * **model resolution** — which hidden dim a request targets when the
//!   server hosts several at once;
//! * **session affinity** — which worker owns a streaming session (a pure
//!   hash of the id, so the mapping is stable across restarts and
//!   independent of any table state);
//! * **dispatch planning** — which worker a stateless request goes to
//!   (round-robin over non-full queues; when everything is full the
//!   least-loaded queue is returned and the caller's blocking send is the
//!   backpressure — requests are never dropped).

use crate::error::Result;

/// The shape of one serving bucket as the router sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketShape {
    /// Padded sequence length T of the bucket's artifact.
    pub t: usize,
    /// Batch capacity B of the bucket's artifact.
    pub b: usize,
}

/// Canonical bucket order: smallest T first (least padding); at equal T
/// the widest B first (the dynamic batcher can then actually group).
pub fn bucket_sort_key(s: &BucketShape) -> (usize, std::cmp::Reverse<usize>) {
    (s.t, std::cmp::Reverse(s.b))
}

/// Pick the bucket for a sequence: the first fitting one in canonical
/// order, i.e. the smallest T >= seq_len, widest B at that T.
pub fn route(shapes: &[BucketShape], seq_len: usize) -> Option<usize> {
    shapes.iter().position(|s| s.t >= seq_len)
}

/// Resolve which hidden dim a request targets. Explicit wins; with one
/// served dim there is nothing to resolve; otherwise the payload width
/// names the variant (the shipped artifacts are square, D == H).
pub fn resolve_hidden(
    dims: &[usize],
    explicit: Option<usize>,
    seq_len: usize,
    payload_len: usize,
) -> Result<usize, String> {
    if let Some(h) = explicit {
        if dims.contains(&h) {
            return Ok(h);
        }
        return Err(format!("hidden dim {h} not served (serving {dims:?})"));
    }
    if dims.len() == 1 {
        return Ok(dims[0]);
    }
    if seq_len > 0 && payload_len % seq_len == 0 {
        let d = payload_len / seq_len;
        if dims.contains(&d) {
            return Ok(d);
        }
    }
    Err(format!(
        "ambiguous model variant: set InferenceRequest::with_hidden (serving {dims:?})"
    ))
}

/// The worker that owns a streaming session. A splitmix64 finalizer over
/// the id: a pure function of (session, workers), so the same session
/// always lands on the same worker — the recurrent (h, c) carry lives in
/// exactly one place — and the mapping survives any store rehash or
/// restart.
pub fn session_worker(session: u64, workers: usize) -> usize {
    let mut z = session.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    (z % workers.max(1) as u64) as usize
}

/// Pick a worker for a stateless request given per-worker queue depths.
/// Round-robin from `rr` over workers with room; if every queue is full,
/// return the least-loaded one anyway — the caller's blocking send then
/// applies backpressure instead of dropping.
pub fn plan_dispatch(depths: &[usize], queue_cap: usize, rr: usize) -> usize {
    let n = depths.len();
    debug_assert!(n > 0, "plan_dispatch needs at least one worker");
    for k in 0..n {
        let i = (rr + k) % n;
        if depths[i] < queue_cap {
            return i;
        }
    }
    (0..n).min_by_key(|&i| depths[i]).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes(raw: &[(usize, usize)]) -> Vec<BucketShape> {
        let mut v: Vec<BucketShape> = raw.iter().map(|&(t, b)| BucketShape { t, b }).collect();
        v.sort_by_key(bucket_sort_key);
        v
    }

    #[test]
    fn route_smallest_fitting_t_widest_b() {
        // Unsorted input on purpose: the canonical order does the work.
        let s = shapes(&[(32, 4), (16, 1), (16, 4), (8, 1)]);
        assert_eq!(s[0], BucketShape { t: 8, b: 1 });
        assert_eq!(s[1], BucketShape { t: 16, b: 4 });
        // len 4 fits T=8.
        assert_eq!(route(&s, 4), Some(0));
        // len 9 skips T=8; at T=16 the widest B wins.
        assert_eq!(s[route(&s, 9).unwrap()], BucketShape { t: 16, b: 4 });
        // len 17 only fits T=32.
        assert_eq!(s[route(&s, 17).unwrap()], BucketShape { t: 32, b: 4 });
        // Nothing fits len 33.
        assert_eq!(route(&s, 33), None);
    }

    #[test]
    fn resolve_explicit_and_inferred() {
        let dims = [64usize, 256];
        assert_eq!(resolve_hidden(&dims, Some(64), 4, 0), Ok(64));
        assert!(resolve_hidden(&dims, Some(512), 4, 0).is_err());
        // Single served dim needs no hint at all.
        assert_eq!(resolve_hidden(&[256], None, 4, 999), Ok(256));
        // Two dims: the payload width names the variant (D == H).
        assert_eq!(resolve_hidden(&dims, None, 4, 4 * 64), Ok(64));
        assert_eq!(resolve_hidden(&dims, None, 4, 4 * 256), Ok(256));
        // Width matching no served dim is ambiguous.
        assert!(resolve_hidden(&dims, None, 4, 4 * 100).is_err());
        assert!(resolve_hidden(&dims, None, 0, 0).is_err());
    }

    #[test]
    fn session_affinity_is_stable_and_state_free() {
        // Same (session, workers) -> same worker, every time: the mapping
        // is a pure function, so no rehash/restart can move a session.
        for sid in 0..500u64 {
            let w = session_worker(sid, 4);
            assert!(w < 4);
            for _ in 0..3 {
                assert_eq!(session_worker(sid, 4), w);
            }
        }
        // Degenerate pool sizes stay in range.
        assert_eq!(session_worker(42, 1), 0);
        assert_eq!(session_worker(42, 0), 0);
    }

    #[test]
    fn session_affinity_spreads_load() {
        let n = 4usize;
        let mut counts = vec![0usize; n];
        for sid in 0..4000u64 {
            counts[session_worker(sid, n)] += 1;
        }
        // splitmix64 should land within +/-25% of uniform on 4k ids.
        for &c in &counts {
            assert!((750..=1250).contains(&c), "skewed affinity: {counts:?}");
        }
    }

    #[test]
    fn dispatch_prefers_non_full_queues() {
        // Worker 0 full: round-robin from 0 must skip it.
        assert_eq!(plan_dispatch(&[4, 1, 0], 4, 0), 1);
        // Cursor starts past the full one.
        assert_eq!(plan_dispatch(&[4, 1, 0], 4, 2), 2);
        assert_eq!(plan_dispatch(&[0, 0, 0], 4, 1), 1);
    }

    #[test]
    fn dispatch_backpressures_when_all_full() {
        // Every queue at capacity: still returns a worker (the least
        // loaded), never a drop.
        assert_eq!(plan_dispatch(&[6, 4, 5], 4, 0), 1);
        assert_eq!(plan_dispatch(&[4, 4, 4], 4, 0), 0);
    }
}
