//! The per-connection serve loop: one thread per accepted socket,
//! blocking IO under explicit deadlines, every outcome typed.
//!
//! **Deadline model.** The loop waits for a frame's first length byte in
//! short ticks (so it notices a drain or idle expiry promptly, without a
//! wakeup channel); once a frame has started, the socket deadline
//! switches to `read_timeout` — a peer that opens a frame and then
//! dribbles (slowloris) is killed with a typed `DeadlineExceeded` and
//! counted in `conns_timed_out`. Deadlines are per-`read` syscall, the
//! standard `SO_RCVTIMEO` approximation of a whole-frame budget.
//!
//! **Malformed input.** A frame whose declared length exceeds the cap is
//! rejected before allocation (`TooLarge`) and the connection closes —
//! the unread body means the stream is out of sync. A frame that decodes
//! badly (unknown tag, truncated field, garbled bytes) was still fully
//! consumed, so the loop replies `Malformed` and *keeps serving*: one
//! bad frame does not tear down a healthy connection.
//!
//! **Chaos.** Deterministic network faults fire here, at the raw-frame
//! layer, after a frame is read but before it is decoded: `stall` sleeps,
//! `garble` corrupts the raw bytes (guaranteeing a `Malformed` verdict),
//! `disconnect` drops the socket abruptly — exactly what a killed client
//! or a dying link looks like to the server.
//!
//! **Drain.** Once draining, the connection finishes and flushes the
//! frame it is serving, answers new `Request`/`Begin` frames with the
//! retryable `Draining` verdict (`End` and `Control` still work — they
//! reduce load), and closes after `drain_linger` even if the peer never
//! stops talking.

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::faults::{NetFaultArm, NetFaultKind};
use crate::coordinator::request::InferenceRequest;
use crate::error::SharpError;
use crate::util::json::{self, Json};

use super::frame::{self, Frame, RawOutcome, WireError};
use super::listener::{Shared, STATE_DRAINING};

/// Idle-wait poll period: bounds how stale a connection's view of the
/// drain flag can be.
const TICK: Duration = Duration::from_millis(50);

/// Serve one accepted connection until EOF, deadline, fault, or drain.
pub(super) fn serve(stream: TcpStream, mut arm: NetFaultArm, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    let writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut writer = BufWriter::new(writer);
    serve_loop(&mut reader, &mut writer, &mut arm, shared);
    let _ = writer.flush();
}

fn serve_loop(
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    arm: &mut NetFaultArm,
    shared: &Arc<Shared>,
) {
    let cfg = &shared.cfg;
    let mut idle = Duration::ZERO;
    let mut draining_since: Option<Instant> = None;
    loop {
        // Drain bookkeeping: note when this connection first saw the
        // flag; linger past it only long enough to hand out typed
        // refusals, then close no matter what the peer does.
        if draining_since.is_none() && shared.draining() {
            draining_since = Some(Instant::now());
        }
        if let Some(t0) = draining_since {
            if t0.elapsed() >= cfg.drain_linger {
                shared.counters.drained.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }

        // Phase 1: idle-wait for the first length byte in short ticks.
        if reader.get_ref().set_read_timeout(Some(TICK)).is_err() {
            return;
        }
        let first = match read_first_byte(reader) {
            Ok(Some(b)) => {
                idle = Duration::ZERO;
                b
            }
            // Clean EOF at a frame boundary: the peer hung up. Sessions
            // deliberately survive this — that is what reconnect-resume
            // is built on.
            Ok(None) => return,
            Err(e) if is_timeout(&e) => {
                idle += TICK;
                if idle >= cfg.idle_timeout {
                    shared.counters.timed_out.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                continue;
            }
            Err(_) => return,
        };

        // Phase 2: a frame has started — switch to the slowloris deadline.
        if reader
            .get_ref()
            .set_read_timeout(Some(cfg.read_timeout))
            .is_err()
        {
            return;
        }
        let outcome = match frame::read_raw_after(first, reader, cfg.max_frame) {
            Ok(o) => o,
            Err(e) if is_timeout(&e) => {
                shared.counters.timed_out.fetch_add(1, Ordering::Relaxed);
                let verdict = WireError::Sharp(SharpError::DeadlineExceeded {
                    waited_ms: cfg.read_timeout.as_millis() as u64,
                });
                let _ = frame::write_frame(writer, &Frame::Error { id: 0, err: verdict });
                return;
            }
            Err(_) => return,
        };
        let mut raw = match outcome {
            RawOutcome::Frame(r) => r,
            RawOutcome::TooLarge { size, max } => {
                // The oversized body was never read: the stream is out
                // of sync, so reply and close.
                shared.counters.malformed.fetch_add(1, Ordering::Relaxed);
                let err = WireError::TooLarge { size, max };
                let _ = frame::write_frame(writer, &Frame::Error { id: 0, err });
                return;
            }
            RawOutcome::Eof => return,
        };

        // Phase 3: deterministic network chaos, at the raw-frame layer.
        let mut drop_conn = false;
        for kind in arm.on_frame() {
            match kind {
                NetFaultKind::Stall(d) => std::thread::sleep(d),
                NetFaultKind::Garble => frame::garble(&mut raw),
                NetFaultKind::Disconnect => drop_conn = true,
            }
        }
        if drop_conn {
            // Abrupt: no reply, no shutdown handshake — the socket just
            // dies, exactly like a killed client process.
            return;
        }

        // Phase 4: decode. The body was fully consumed either way, so a
        // malformed frame costs one typed reply, not the connection.
        let parsed = match frame::decode(&raw) {
            Ok(f) => f,
            Err(cause) => {
                shared.counters.malformed.fetch_add(1, Ordering::Relaxed);
                let err = WireError::Malformed(cause);
                if frame::write_frame(writer, &Frame::Error { id: 0, err }).is_err() {
                    return;
                }
                continue;
            }
        };

        // Phase 5: serve it.
        if handle_frame(parsed, writer, shared, draining_since.is_some()).is_err() {
            return;
        }
    }
}

/// Dispatch one decoded frame; `Err` means the reply could not be
/// written and the connection is dead.
fn handle_frame(
    parsed: Frame,
    writer: &mut BufWriter<TcpStream>,
    shared: &Arc<Shared>,
    draining: bool,
) -> std::io::Result<()> {
    match parsed {
        Frame::Request {
            id,
            session,
            hidden,
            deadline_ms,
            attempt,
            model,
            seq_len,
            payload,
        } => {
            if attempt > 0 {
                shared.counters.retries.fetch_add(1, Ordering::Relaxed);
            }
            if draining {
                let err = WireError::Draining;
                return frame::write_frame(writer, &Frame::Error { id, err });
            }
            let mut req = InferenceRequest::new(id, seq_len as usize, payload);
            if let Some(s) = session {
                req = req.with_session(s);
            }
            if let Some(h) = hidden {
                req = req.with_hidden(h as usize);
            }
            if let Some(m) = model {
                req = req.with_model(m);
            }
            if let Some(d) = deadline_ms {
                req = req.with_deadline(Duration::from_millis(u64::from(d)));
            }
            let reply = match shared.server.try_infer(req) {
                Ok(resp) => Frame::Response {
                    id,
                    session_steps: resp.session_steps,
                    latency_us: (resp.latency_s * 1e6) as u64,
                    batch: resp.batch_size as u32,
                    h_t: resp.h_t,
                },
                Err(e) => Frame::Error { id, err: e.into() },
            };
            frame::write_frame(writer, &reply)
        }
        // Errors for session lifecycle frames correlate on `id = session`.
        Frame::Begin { session, hidden } => {
            if draining {
                let err = WireError::Draining;
                return frame::write_frame(writer, &Frame::Error { id: session, err });
            }
            let Some(h) = hidden else {
                let err = WireError::Sharp(SharpError::Rejected(
                    "begin requires an explicit hidden dim over the wire".to_string(),
                ));
                return frame::write_frame(writer, &Frame::Error { id: session, err });
            };
            let reply = match shared.server.try_begin_session(session, h as usize) {
                Ok(()) => Frame::Begun { session },
                Err(e) => Frame::Error {
                    id: session,
                    err: e.into(),
                },
            };
            frame::write_frame(writer, &reply)
        }
        // `End` works even while draining: it sheds load, and its reply
        // carries the final carry the client may want to bit-compare.
        Frame::End { session } => {
            let reply = match shared.server.end_session(session) {
                Ok(state) => Frame::Ended {
                    session,
                    state: state.map(|s| (s.steps, s.h, s.c)),
                },
                Err(_) => Frame::Error {
                    id: session,
                    err: WireError::Sharp(SharpError::WorkerFailed {
                        worker: None,
                        reason: "server terminated".to_string(),
                    }),
                },
            };
            frame::write_frame(writer, &reply)
        }
        Frame::Control { body } => {
            let reply = control_reply(shared, &body);
            frame::write_frame(writer, &Frame::ControlReply { body: reply })
        }
        // Server→client frames arriving at the server are a protocol
        // violation by a confused peer — typed rejection, stream stays
        // in sync, keep serving.
        Frame::Response { id, .. } | Frame::Error { id, .. } => {
            shared.counters.malformed.fetch_add(1, Ordering::Relaxed);
            let err = WireError::Malformed("server-direction frame sent to server".to_string());
            frame::write_frame(writer, &Frame::Error { id, err })
        }
        Frame::Begun { session } | Frame::Ended { session, .. } => {
            shared.counters.malformed.fetch_add(1, Ordering::Relaxed);
            let err = WireError::Malformed("server-direction frame sent to server".to_string());
            frame::write_frame(writer, &Frame::Error { id: session, err })
        }
        Frame::ControlReply { .. } => {
            shared.counters.malformed.fetch_add(1, Ordering::Relaxed);
            let err = WireError::Malformed("server-direction frame sent to server".to_string());
            frame::write_frame(writer, &Frame::Error { id: 0, err })
        }
    }
}

/// The JSON control plane: `{"cmd":"health"|"metrics"|"drain"}`.
fn control_reply(shared: &Arc<Shared>, body: &str) -> String {
    let parsed = match json::parse(body) {
        Ok(j) => j,
        Err(e) => return error_body(&format!("bad control JSON: {e}")),
    };
    match parsed.get("cmd").and_then(Json::as_str) {
        Some("health") => {
            let mut o = BTreeMap::new();
            o.insert("ok".to_string(), Json::Bool(true));
            o.insert("state".to_string(), Json::Str(state_name(shared)));
            o.insert(
                "live_conns".to_string(),
                Json::Num(shared.live.load(Ordering::Relaxed) as f64),
            );
            json::write(&Json::Obj(o))
        }
        Some("metrics") => match shared.metrics() {
            Ok(mut m) => {
                let mut o = BTreeMap::new();
                o.insert("ok".to_string(), Json::Bool(true));
                o.insert("metrics".to_string(), m.snapshot_json());
                json::write(&Json::Obj(o))
            }
            Err(e) => error_body(&format!("metrics snapshot failed: {e}")),
        },
        Some("drain") => {
            shared.state.store(STATE_DRAINING, Ordering::Release);
            let mut o = BTreeMap::new();
            o.insert("ok".to_string(), Json::Bool(true));
            o.insert("state".to_string(), Json::Str("draining".to_string()));
            json::write(&Json::Obj(o))
        }
        Some(other) => error_body(&format!("unknown control cmd '{other}'")),
        None => error_body("control body needs a string 'cmd' field"),
    }
}

fn state_name(shared: &Arc<Shared>) -> String {
    if shared.draining() {
        "draining".to_string()
    } else {
        "running".to_string()
    }
}

fn error_body(msg: &str) -> String {
    let mut o = BTreeMap::new();
    o.insert("ok".to_string(), Json::Bool(false));
    o.insert("error".to_string(), Json::Str(msg.to_string()));
    json::write(&Json::Obj(o))
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Read exactly one byte, treating `Ok(0)` as clean EOF and retrying
/// `Interrupted` — the idle-wait probe for a frame's first length byte.
fn read_first_byte(r: &mut impl Read) -> std::io::Result<Option<u8>> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(b[0])),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}
