//! Blocking TCP client with typed verdicts, capped exponential backoff
//! with deterministic jitter, and mid-stream reconnect.
//!
//! Two failure planes, kept distinct on purpose:
//! - **Transport errors** (`Err(...)` from every method): the socket
//!   died, timed out, or spoke gibberish. The client drops the
//!   connection and lazily reconnects on the next call — streaming
//!   sessions live on the *server*, so a reconnected client resumes its
//!   session by id and the carried state is bit-exact (checked against
//!   `session_steps`: a reset to 1 means the carry was lost).
//! - **Typed verdicts** (`Ok(Err(WireError))`): the server answered and
//!   said no. [`WireError::retryable`] splits shed/draining/worker-death
//!   (retry with backoff) from deterministic failures (give up).
//!
//! Retry semantics: a retried *verdict* is exactly-once safe — the
//! refusal means the request never executed. A retry after a *transport*
//! error is at-least-once: the request may have executed before the
//! reply was lost. Streaming callers detect the duplicate through
//! `session_steps` (it advances by one per executed chunk).

use std::io::BufReader;
use std::net::TcpStream;
use std::time::Duration;

use crate::error::{anyhow, bail, Context, Result};
use crate::util::rng::Rng;

use super::frame::{self, Frame, RawOutcome, WireError, DEFAULT_MAX_FRAME};

/// One request as the client API sees it (mirrors [`Frame::Request`]
/// minus the wire-only `attempt` counter, which the retry loop owns).
#[derive(Debug, Clone, PartialEq)]
pub struct NetRequest {
    pub id: u64,
    pub session: Option<u64>,
    pub hidden: Option<u32>,
    pub deadline_ms: Option<u32>,
    pub model: Option<String>,
    pub seq_len: u32,
    pub payload: Vec<f32>,
}

impl NetRequest {
    /// A stateless request with just shape + payload.
    pub fn new(id: u64, seq_len: u32, payload: Vec<f32>) -> NetRequest {
        NetRequest {
            id,
            session: None,
            hidden: None,
            deadline_ms: None,
            model: None,
            seq_len,
            payload,
        }
    }
}

/// A successful verdict (mirrors [`Frame::Response`]).
#[derive(Debug, Clone, PartialEq)]
pub struct NetResponse {
    pub id: u64,
    pub session_steps: Option<u64>,
    pub latency_us: u64,
    pub batch: u32,
    pub h_t: Vec<f32>,
}

/// Backoff/retry policy for [`NetClient::infer_retry`]: capped
/// exponential with deterministic jitter (seeded, so chaos tests
/// replay identically).
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total tries (first attempt included). At least 1.
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles per retry.
    pub base: Duration,
    /// Backoff ceiling.
    pub cap: Duration,
    /// Jitter seed; the sleep is uniform in `[backoff/2, backoff]`.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 6,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(500),
            seed: 0x5eed_cafe,
        }
    }
}

impl RetryPolicy {
    /// The jittered sleep before retry number `attempt` (0-based): the
    /// capped exponential `min(base << attempt, cap)`, scaled by a
    /// uniform factor in `[0.5, 1.0]` from `rng`.
    fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = self
            .base
            .saturating_mul(1u32 << attempt.min(20))
            .min(self.cap);
        let nanos = exp.as_nanos().min(u128::from(u64::MAX)) as u64;
        if nanos == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(rng.range_u64(nanos / 2, nanos))
    }
}

/// A blocking client over one TCP connection, reconnecting lazily.
pub struct NetClient {
    addr: String,
    io_timeout: Duration,
    stream: Option<BufReader<TcpStream>>,
    rng: Rng,
    /// Times the transport was torn down and re-dialed (observability
    /// for loadgen and the chaos tests).
    pub reconnects: u64,
}

impl NetClient {
    /// Dial `addr` (eagerly, so bind errors surface here) with one IO
    /// timeout governing connect, reads, and writes.
    pub fn connect(addr: impl Into<String>, io_timeout: Duration) -> Result<NetClient> {
        let mut c = NetClient {
            addr: addr.into(),
            io_timeout,
            stream: None,
            rng: Rng::new(RetryPolicy::default().seed),
            reconnects: 0,
        };
        c.ensure_connected()?;
        c.reconnects = 0; // the initial dial is not a re-connect
        Ok(c)
    }

    /// Re-seed the jitter source (chaos tests pin it for determinism).
    pub fn seed_jitter(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
    }

    /// Drop the connection without telling the server — the test hook
    /// that simulates a client-side link death. The next call re-dials.
    pub fn disconnect(&mut self) {
        self.stream = None;
    }

    fn ensure_connected(&mut self) -> Result<()> {
        if self.stream.is_some() {
            return Ok(());
        }
        let addr = std::net::ToSocketAddrs::to_socket_addrs(&self.addr)
            .with_context(|| format!("resolving {}", self.addr))?
            .next()
            .ok_or_else(|| anyhow!("{} resolved to no address", self.addr))?;
        let stream = TcpStream::connect_timeout(&addr, self.io_timeout)
            .with_context(|| format!("connecting to {}", self.addr))?;
        stream
            .set_read_timeout(Some(self.io_timeout))
            .context("setting client read timeout")?;
        stream
            .set_write_timeout(Some(self.io_timeout))
            .context("setting client write timeout")?;
        let _ = stream.set_nodelay(true);
        self.reconnects += 1;
        self.stream = Some(BufReader::new(stream));
        Ok(())
    }

    /// One request/reply exchange. Any transport failure drops the
    /// connection (next call reconnects) and surfaces as `Err`.
    fn roundtrip(&mut self, out: &Frame) -> Result<Frame> {
        self.ensure_connected()?;
        let r = self.exchange(out);
        if r.is_err() {
            self.stream = None;
        }
        r
    }

    fn exchange(&mut self, out: &Frame) -> Result<Frame> {
        let Some(reader) = self.stream.as_mut() else {
            bail!("not connected");
        };
        frame::write_frame(reader.get_mut(), out).context("writing frame")?;
        match frame::read_raw(reader, DEFAULT_MAX_FRAME).context("reading reply")? {
            RawOutcome::Frame(raw) => {
                frame::decode(&raw).map_err(|c| anyhow!("malformed server frame: {c}"))
            }
            RawOutcome::TooLarge { size, max } => {
                bail!("server frame too large: {size} > {max}")
            }
            RawOutcome::Eof => bail!("server closed the connection"),
        }
    }

    /// Send one inference/chunk request; `attempt` goes on the wire so
    /// the server can meter observed retry pressure.
    pub fn request(
        &mut self,
        req: &NetRequest,
        attempt: u16,
    ) -> Result<Result<NetResponse, WireError>> {
        let out = Frame::Request {
            id: req.id,
            session: req.session,
            hidden: req.hidden,
            deadline_ms: req.deadline_ms,
            attempt,
            model: req.model.clone(),
            seq_len: req.seq_len,
            payload: req.payload.clone(),
        };
        match self.roundtrip(&out)? {
            Frame::Response {
                id,
                session_steps,
                latency_us,
                batch,
                h_t,
            } => Ok(Ok(NetResponse {
                id,
                session_steps,
                latency_us,
                batch,
                h_t,
            })),
            Frame::Error { err, .. } => Ok(Err(err)),
            other => bail!("protocol violation: expected RESPONSE/ERROR, got {other:?}"),
        }
    }

    /// Open a streaming session.
    pub fn begin(&mut self, session: u64, hidden: u32) -> Result<Result<(), WireError>> {
        let out = Frame::Begin {
            session,
            hidden: Some(hidden),
        };
        match self.roundtrip(&out)? {
            Frame::Begun { .. } => Ok(Ok(())),
            Frame::Error { err, .. } => Ok(Err(err)),
            other => bail!("protocol violation: expected BEGUN/ERROR, got {other:?}"),
        }
    }

    /// Close a streaming session; `Ok(Ok(Some((steps, h, c))))` is the
    /// final carry, bit-exact off the wire.
    #[allow(clippy::type_complexity)]
    pub fn end(
        &mut self,
        session: u64,
    ) -> Result<Result<Option<(u64, Vec<f32>, Vec<f32>)>, WireError>> {
        match self.roundtrip(&Frame::End { session })? {
            Frame::Ended { state, .. } => Ok(Ok(state)),
            Frame::Error { err, .. } => Ok(Err(err)),
            other => bail!("protocol violation: expected ENDED/ERROR, got {other:?}"),
        }
    }

    /// One control-plane exchange; returns the raw JSON reply body.
    pub fn control(&mut self, body: &str) -> Result<String> {
        let out = Frame::Control {
            body: body.to_string(),
        };
        match self.roundtrip(&out)? {
            Frame::ControlReply { body } => Ok(body),
            Frame::Error { err, .. } => bail!("control refused: {err}"),
            other => bail!("protocol violation: expected CONTROL_REPLY, got {other:?}"),
        }
    }

    /// [`NetClient::request`] wrapped in the retry loop: reconnect +
    /// resend on transport errors, backoff + resend on retryable
    /// verdicts, fail fast on deterministic ones. Returns the response
    /// plus how many tries it took (for loadgen's retry accounting).
    pub fn infer_retry(
        &mut self,
        req: &NetRequest,
        policy: &RetryPolicy,
    ) -> Result<(NetResponse, u32)> {
        let tries = policy.max_attempts.max(1);
        let mut attempt: u32 = 0;
        loop {
            let attempt_no = attempt.min(u32::from(u16::MAX)) as u16;
            match self.request(req, attempt_no) {
                Ok(Ok(resp)) => return Ok((resp, attempt + 1)),
                Ok(Err(err)) if err.retryable() && attempt + 1 < tries => {
                    std::thread::sleep(policy.backoff(attempt, &mut self.rng));
                    attempt += 1;
                }
                Ok(Err(err)) if err.retryable() => {
                    bail!("gave up after {tries} attempts; last verdict: {err}")
                }
                Ok(Err(err)) => bail!("non-retryable verdict: {err}"),
                Err(transport) if attempt + 1 < tries => {
                    // The connection is already torn down; back off, then
                    // the next `request` re-dials. At-least-once from
                    // here on — see the module docs.
                    let _ = transport;
                    std::thread::sleep(policy.backoff(attempt, &mut self.rng));
                    attempt += 1;
                }
                Err(transport) => {
                    return Err(transport
                        .context(format!("gave up after {tries} attempts (transport)")))
                }
            }
        }
    }
}
