//! Accept loop, shared front-end state, and the graceful-drain state
//! machine (DESIGN.md §13).
//!
//! One accept thread owns the `TcpListener` (non-blocking + short poll,
//! so it notices a drain without a wakeup socket) and spawns one serve
//! thread per accepted connection. Accepted connections get 1-based
//! ordinals — the identity the network fault grammar targets
//! (`disconnect@conn3:frame7`). Over the connection cap, the socket is
//! answered with a typed, retryable `Overloaded` error frame and closed:
//! the wire-level continuation of `OverloadPolicy::Shed`.
//!
//! **Drain state machine** (`RUNNING → DRAINING → drained`):
//! 1. `drain()` — or the control-plane `{"cmd":"drain"}` — flips the
//!    shared state; it is idempotent.
//! 2. The accept loop stops accepting and joins every connection thread.
//!    Each connection finishes the frame it is serving, flushes the
//!    reply, answers anything newly arriving with a retryable
//!    [`WireError::Draining`](super::frame::WireError) verdict, and
//!    closes after a bounded linger.
//! 3. [`Listener::wait`] then fences every live streaming session
//!    ([`Server::fence_sessions`] — forced fuse drain, the `End`
//!    semantics pool-wide) and finally shuts the pool down by dropping
//!    the server. Nothing in flight is dropped at any step.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{anyhow, Context, Result};

use super::super::faults::{FaultPlan, NetFaultArm};
use super::super::metrics::Metrics;
use super::super::server::Server;
use super::conn;
use super::frame::{self, Frame, WireError, DEFAULT_MAX_FRAME};
use crate::error::SharpError;

/// Front-end lifecycle states (the `state` atomic in [`Shared`]).
pub(super) const STATE_RUNNING: u8 = 0;
pub(super) const STATE_DRAINING: u8 = 1;

/// TCP front-end configuration.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port; read the
    /// actual one from [`Listener::local_addr`]).
    pub addr: String,
    /// Concurrent-connection cap; connections beyond it are answered
    /// with a retryable `Overloaded` error frame and closed.
    pub max_conns: usize,
    /// Per-frame payload-size cap (bytes); larger frames are rejected
    /// with a typed `TooLarge` error before any allocation.
    pub max_frame: usize,
    /// Mid-frame read deadline: once a frame's first byte has arrived,
    /// the rest must follow within this budget or the connection is
    /// killed (the slowloris defense).
    pub read_timeout: Duration,
    /// Per-write deadline when flushing replies to a slow peer.
    pub write_timeout: Duration,
    /// Idle deadline: a connection that sends nothing at all for this
    /// long is closed (counted in `conns_timed_out`).
    pub idle_timeout: Duration,
    /// How long a draining connection lingers to hand out typed
    /// `Draining` refusals before closing. Bounds drain latency even
    /// against a client that never stops sending.
    pub drain_linger: Duration,
    /// Deterministic network-fault schedule (`disconnect@conn…`,
    /// `stall@conn…`, `garble@conn…`). `None` falls back to the
    /// `SHARP_FAULTS` env var at `start`, mirroring `ServerConfig`.
    pub faults: Option<FaultPlan>,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:0".to_string(),
            max_conns: 64,
            max_frame: DEFAULT_MAX_FRAME,
            read_timeout: Duration::from_secs(2),
            write_timeout: Duration::from_secs(2),
            idle_timeout: Duration::from_secs(60),
            drain_linger: Duration::from_millis(500),
            faults: None,
        }
    }
}

/// Lock-free connection counters owned by the front-end (workers never
/// see connections), folded into [`Metrics`] snapshots on demand.
#[derive(Debug, Default)]
pub(super) struct NetCounters {
    pub accepted: AtomicU64,
    pub rejected: AtomicU64,
    pub timed_out: AtomicU64,
    pub drained: AtomicU64,
    pub malformed: AtomicU64,
    pub retries: AtomicU64,
}

impl NetCounters {
    pub(super) fn fold_into(&self, m: &mut Metrics) {
        m.conns_accepted += self.accepted.load(Ordering::Relaxed);
        m.conns_rejected += self.rejected.load(Ordering::Relaxed);
        m.conns_timed_out += self.timed_out.load(Ordering::Relaxed);
        m.conns_drained += self.drained.load(Ordering::Relaxed);
        m.frames_malformed += self.malformed.load(Ordering::Relaxed);
        m.retries_observed += self.retries.load(Ordering::Relaxed);
    }
}

/// State shared between the accept loop, every connection thread, and
/// the [`Listener`] handle.
pub(super) struct Shared {
    pub server: Server,
    pub cfg: NetConfig,
    pub state: AtomicU8,
    pub counters: NetCounters,
    /// Live (accepted, not yet closed) connections — the cap gauge and
    /// the `depth` reported in wire `Overloaded` rejections.
    pub live: AtomicUsize,
}

impl Shared {
    pub(super) fn draining(&self) -> bool {
        self.state.load(Ordering::Acquire) == STATE_DRAINING
    }

    /// Merged pool metrics with the front-end connection counters folded
    /// in — the one snapshot path `render`, `snapshot_json`, and the
    /// control plane all share.
    pub(super) fn metrics(&self) -> Result<Metrics> {
        let mut m = self.server.metrics()?;
        self.counters.fold_into(&mut m);
        Ok(m)
    }
}

/// What a completed drain handed back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DrainSummary {
    /// Streaming sessions fenced (ended with the forced fuse drain) at
    /// teardown.
    pub fenced: usize,
    /// Connections that were closed by the drain (each flushed its
    /// in-flight reply first).
    pub conns_drained: u64,
}

/// Handle to a running TCP front-end. Owns the [`Server`]: dropping the
/// listener (after [`Listener::wait`]) is what shuts the pool down,
/// which keeps the teardown order fixed — stop accepting, drain
/// connections, fence sessions, then pool shutdown.
pub struct Listener {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    local_addr: SocketAddr,
}

impl Listener {
    /// Bind `cfg.addr` and start serving `server` over it.
    pub fn start(server: Server, cfg: NetConfig) -> Result<Listener> {
        let mut cfg = cfg;
        if cfg.faults.is_none() {
            cfg.faults = FaultPlan::from_env()?;
        }
        let sock = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding TCP front-end to {}", cfg.addr))?;
        let local_addr = sock
            .local_addr()
            .context("reading bound address of the TCP front-end")?;
        sock.set_nonblocking(true)
            .context("setting the accept socket non-blocking")?;
        let shared = Arc::new(Shared {
            server,
            cfg,
            state: AtomicU8::new(STATE_RUNNING),
            counters: NetCounters::default(),
            live: AtomicUsize::new(0),
        });
        let for_accept = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("sharp-accept".to_string())
            .spawn(move || accept_loop(&sock, &for_accept))
            .map_err(|e| anyhow!("spawning the accept thread: {e}"))?;
        Ok(Listener {
            shared,
            accept: Some(accept),
            local_addr,
        })
    }

    /// The address actually bound (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Begin a graceful drain (idempotent): stop accepting, linger-close
    /// connections with typed retryable refusals for new work. Pair with
    /// [`Listener::wait`] to block until torn down.
    pub fn drain(&self) {
        self.shared.state.store(STATE_DRAINING, Ordering::Release);
    }

    /// Snapshot of pool metrics with connection counters folded in.
    pub fn metrics(&self) -> Result<Metrics> {
        self.shared.metrics()
    }

    /// Block until the front-end has drained (via [`Listener::drain`] or
    /// the control plane), then run the back half of the ordered
    /// teardown: fence every live streaming session and shut the pool
    /// down. Returns what the drain did.
    pub fn wait(mut self) -> Result<DrainSummary> {
        if let Some(h) = self.accept.take() {
            h.join()
                .map_err(|_| anyhow!("the accept thread panicked"))?;
        }
        let fenced = self
            .shared
            .server
            .fence_sessions()
            .context("fencing streaming sessions at drain")?;
        let conns_drained = self.shared.counters.drained.load(Ordering::Relaxed);
        // `self` drops here; with every connection thread joined, this is
        // the last strong ref — dropping `Shared` drops the `Server`,
        // whose `Drop` runs the pool shutdown (fence again — a no-op now
        // — then join every worker).
        Ok(DrainSummary {
            fenced,
            conns_drained,
        })
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        // A listener dropped without `wait()` must not leave the accept
        // thread (and through it the pool) running detached.
        self.drain();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Accept-poll period: how quickly the loop notices a drain.
const ACCEPT_TICK: Duration = Duration::from_millis(5);

fn accept_loop(sock: &TcpListener, shared: &Arc<Shared>) {
    let mut ordinal: u64 = 0;
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !shared.draining() {
        match sock.accept() {
            Ok((stream, _peer)) => {
                // Handles of finished connections are reaped here so a
                // long-lived server doesn't accumulate them.
                conns.retain(|h| !h.is_finished());
                let live = shared.live.load(Ordering::Relaxed);
                if live >= shared.cfg.max_conns {
                    reject_over_cap(stream, live, shared);
                    continue;
                }
                ordinal += 1;
                shared.counters.accepted.fetch_add(1, Ordering::Relaxed);
                shared.live.fetch_add(1, Ordering::Relaxed);
                let arm = NetFaultArm::new(shared.cfg.faults.as_ref(), ordinal);
                let for_conn = Arc::clone(shared);
                let spawned = std::thread::Builder::new()
                    .name(format!("sharp-conn{ordinal}"))
                    .spawn(move || {
                        conn::serve(stream, arm, &for_conn);
                        for_conn.live.fetch_sub(1, Ordering::Relaxed);
                    });
                match spawned {
                    Ok(h) => conns.push(h),
                    // Thread exhaustion is an overload condition, not a
                    // crash: undo the gauges and meter the shed. The
                    // stream died inside the failed spawn, so no reply
                    // can be written.
                    Err(_) => {
                        shared.live.fetch_sub(1, Ordering::Relaxed);
                        shared.counters.accepted.fetch_sub(1, Ordering::Relaxed);
                        shared.counters.rejected.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_TICK);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            // Transient accept errors (ECONNABORTED and friends): the
            // listener socket itself is fine, keep serving.
            Err(_) => std::thread::sleep(ACCEPT_TICK),
        }
    }
    // Draining: every connection thread lingers at most
    // `drain_linger` + one in-flight frame; join them all so `wait()`
    // can fence sessions knowing no connection still writes.
    for h in conns {
        let _ = h.join();
    }
}

/// Answer an over-cap connection with a typed, retryable `Overloaded`
/// frame (the wire continuation of `OverloadPolicy::Shed`) and close it.
fn reject_over_cap(stream: TcpStream, live: usize, shared: &Arc<Shared>) {
    shared.counters.rejected.fetch_add(1, Ordering::Relaxed);
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    let verdict = Frame::Error {
        id: 0,
        err: WireError::Sharp(SharpError::Overloaded {
            depth: live,
            watermark: shared.cfg.max_conns,
        }),
    };
    let mut w = stream;
    let _ = frame::write_frame(&mut w, &verdict);
    // `w` drops here: close.
}
