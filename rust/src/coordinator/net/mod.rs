//! TCP serving front-end (DESIGN.md §13): a hardened, std-only network
//! layer over [`Server`](super::Server) — no async runtime, no external
//! crates, one thread per connection over the same bounded worker pool.
//!
//! Layout:
//! - [`frame`]: the wire format — length-prefixed binary frames, BE
//!   integers, f32 tensors as IEEE-754 bit patterns (bit-exact), typed
//!   [`frame::WireError`] verdicts mirroring `SharpError`.
//! - [`listener`]: accept loop + shared state — connection cap with
//!   typed `Overloaded` rejection, graceful drain (stop accepting →
//!   fence in-flight streaming sessions → pool shutdown), connection
//!   counters folded into the metrics snapshot.
//! - [`conn`]: the per-connection serve loop — idle/slowloris deadlines,
//!   malformed-frame rejection without losing stream sync, deterministic
//!   network chaos (`disconnect@connN:frameM`, `stall@connN:50ms`,
//!   `garble@connN:frameM`) fired at the raw-frame layer.
//! - [`client`]: a blocking client with capped exponential backoff +
//!   jittered retry on retryable verdicts and mid-stream reconnect
//!   (sessions live on the server, so a resumed stream stays bit-exact).

pub mod client;
pub mod conn;
pub mod frame;
pub mod listener;

pub use client::{NetClient, NetRequest, NetResponse, RetryPolicy};
pub use frame::{Frame, WireError};
pub use listener::{DrainSummary, Listener, NetConfig};
