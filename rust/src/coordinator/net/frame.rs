//! Wire format of the TCP front-end: length-prefixed binary frames.
//!
//! Every frame on the wire is `[len: u32 BE][tag: u8][payload: len bytes]`
//! — `len` counts the payload only, so a reader always knows exactly how
//! many bytes to consume before the next frame boundary. All integers are
//! big-endian; `f32` tensors travel as their IEEE-754 bit patterns
//! (`to_bits`/`from_bits`), so a hidden-state vector round-trips the wire
//! **bit-exactly** — the property the reconnect-resume chaos test pins.
//!
//! Client → server: [`Frame::Request`] (one inference / one streaming
//! chunk), [`Frame::Begin`] / [`Frame::End`] (session lifecycle, PR 5
//! semantics), [`Frame::Control`] (JSON control plane: health, metrics,
//! drain). Server → client: [`Frame::Response`], [`Frame::Error`] (a
//! typed [`WireError`] verdict), [`Frame::Begun`], [`Frame::Ended`]
//! (carrying the final session state so clients can bit-compare), and
//! [`Frame::ControlReply`].
//!
//! Robustness contract: [`read_raw`] rejects frames above a configured
//! size cap *before* allocating ([`RawOutcome::TooLarge`]), reports clean
//! EOF at a frame boundary as [`RawOutcome::Eof`] (mid-frame EOF is an
//! IO error — the peer died), and [`decode`] turns any structural defect
//! (unknown tag, truncated field, over-long vector) into a descriptive
//! `Err` the connection layer converts to [`WireError::Malformed`]
//! without losing stream sync (the body was fully consumed).

use crate::error::SharpError;
use std::io::{Read, Write};

/// Default per-frame size cap (payload bytes): generous for any real
/// chunk (a 4096-wide f32 hidden state is 16 KiB) while bounding what a
/// hostile or corrupt peer can make the server allocate.
pub const DEFAULT_MAX_FRAME: usize = 16 << 20;

/// Frame type tags. Client → server tags have the top bit clear; server
/// → client tags have it set, so a direction-confused peer is caught as
/// an unknown tag instead of a misparse.
pub const TAG_REQUEST: u8 = 0x01;
pub const TAG_BEGIN: u8 = 0x02;
pub const TAG_END: u8 = 0x03;
pub const TAG_CONTROL: u8 = 0x04;
pub const TAG_RESPONSE: u8 = 0x81;
pub const TAG_ERROR: u8 = 0x82;
pub const TAG_BEGUN: u8 = 0x83;
pub const TAG_ENDED: u8 = 0x84;
pub const TAG_CONTROL_REPLY: u8 = 0x85;

/// One decoded frame (either direction).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// One inference request or streaming chunk. `attempt` counts
    /// client-side retries (0 = first send) so the server can meter
    /// observed retry pressure; `deadline_ms` maps onto
    /// `InferenceRequest::deadline`.
    Request {
        id: u64,
        session: Option<u64>,
        hidden: Option<u32>,
        deadline_ms: Option<u32>,
        attempt: u16,
        model: Option<String>,
        seq_len: u32,
        payload: Vec<f32>,
    },
    /// Open a streaming session (fence semantics on the worker).
    Begin { session: u64, hidden: Option<u32> },
    /// Close a streaming session; the reply carries the final state.
    End { session: u64 },
    /// JSON control-plane command (`{"cmd":"health"|"metrics"|"drain"}`).
    Control { body: String },
    /// Successful verdict for a [`Frame::Request`].
    Response {
        id: u64,
        /// Session chunk count after this chunk (`None` = stateless).
        /// Resumed clients compare this against their own count: a
        /// reset to 1 means the carry was lost (LRU eviction/restart).
        session_steps: Option<u64>,
        latency_us: u64,
        batch: u32,
        h_t: Vec<f32>,
    },
    /// Typed failure verdict. `id` correlates to the request (0 when the
    /// error is connection-level, e.g. a malformed frame or a
    /// connection-cap rejection before any request was read).
    Error { id: u64, err: WireError },
    /// Acknowledges a [`Frame::Begin`].
    Begun { session: u64 },
    /// Acknowledges a [`Frame::End`], shipping the final carry (if the
    /// session had state) so clients can bit-compare against a
    /// reference.
    Ended {
        session: u64,
        /// `(steps, h, c)` of the ended session; `None` when the
        /// session had no live state.
        state: Option<(u64, Vec<f32>, Vec<f32>)>,
    },
    /// JSON control-plane reply.
    ControlReply { body: String },
}

/// Typed wire errors: the serving verdicts of [`SharpError`] plus the
/// three failure classes only the network layer can produce. The
/// `retryable` bit travels on the wire so non-Rust clients can implement
/// backoff without reproducing the variant table.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// A coordinator verdict, round-tripped losslessly.
    Sharp(SharpError),
    /// The frame violated the wire format (unknown tag, truncated
    /// field, garbled body). Not retryable: resending the same bytes
    /// reproduces it.
    Malformed(String),
    /// The frame exceeded the server's size cap. Not retryable.
    TooLarge { size: u64, max: u64 },
    /// The server is draining: it finishes in-flight work but admits
    /// nothing new. Retryable — another replica (or this one, later)
    /// can serve it.
    Draining,
}

/// Wire error codes (byte 8 of the ERROR payload).
const CODE_REJECTED: u8 = 1;
const CODE_EXEC_FAILED: u8 = 2;
const CODE_DEADLINE: u8 = 3;
const CODE_OVERLOADED: u8 = 4;
const CODE_WORKER_FAILED: u8 = 5;
const CODE_MALFORMED: u8 = 6;
const CODE_TOO_LARGE: u8 = 7;
const CODE_DRAINING: u8 = 8;

impl WireError {
    /// Stable numeric code for the wire.
    pub fn code(&self) -> u8 {
        match self {
            WireError::Sharp(SharpError::Rejected(_)) => CODE_REJECTED,
            WireError::Sharp(SharpError::ExecFailed(_)) => CODE_EXEC_FAILED,
            WireError::Sharp(SharpError::DeadlineExceeded { .. }) => CODE_DEADLINE,
            WireError::Sharp(SharpError::Overloaded { .. }) => CODE_OVERLOADED,
            WireError::Sharp(SharpError::WorkerFailed { .. }) => CODE_WORKER_FAILED,
            WireError::Malformed(_) => CODE_MALFORMED,
            WireError::TooLarge { .. } => CODE_TOO_LARGE,
            WireError::Draining => CODE_DRAINING,
        }
    }

    /// Whether a client should retry (with backoff) after this verdict.
    /// `Overloaded` is load shedding, `WorkerFailed` is a transient
    /// replica death, `Draining` means "go elsewhere / come back" — all
    /// retryable. Everything else reproduces on resend.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            WireError::Sharp(SharpError::Overloaded { .. })
                | WireError::Sharp(SharpError::WorkerFailed { .. })
                | WireError::Draining
        )
    }
}

impl From<SharpError> for WireError {
    fn from(e: SharpError) -> WireError {
        WireError::Sharp(e)
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Sharp(e) => write!(f, "{e}"),
            WireError::Malformed(msg) => write!(f, "malformed frame: {msg}"),
            WireError::TooLarge { size, max } => {
                write!(f, "frame too large: {size} bytes > cap {max}")
            }
            WireError::Draining => write!(f, "server draining: not accepting new work"),
        }
    }
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

/// Byte-level encoder: big-endian integers into a growing buffer.
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }
    /// Length-prefixed UTF-8 string (u16 length: names and JSON bodies
    /// under 64 KiB; the control plane never needs more).
    fn str16(&mut self, s: &str) {
        let bytes = s.as_bytes();
        let n = bytes.len().min(u16::MAX as usize);
        self.u16(n as u16);
        self.buf.extend_from_slice(&bytes[..n]);
    }
    /// Length-prefixed UTF-8 string (u32 length) for control bodies.
    fn str32(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    /// Count-prefixed f32 vector, each element as BE bits.
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.u32(x.to_bits());
        }
    }
}

/// Byte-level decoder over one frame body; every accessor fails with a
/// position-stamped message instead of panicking, so a truncated or
/// garbled body becomes a typed `Malformed` verdict.
struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.b.len() {
            return Err(format!(
                "truncated: need {n} bytes at offset {}, body is {}",
                self.pos,
                self.b.len()
            ));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, String> {
        let s = self.take(2)?;
        Ok(u16::from_be_bytes([s[0], s[1]]))
    }
    fn u32(&mut self) -> Result<u32, String> {
        let s = self.take(4)?;
        Ok(u32::from_be_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn u64(&mut self) -> Result<u64, String> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_be_bytes(a))
    }
    fn str16(&mut self) -> Result<String, String> {
        let n = self.u16()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| "non-UTF-8 string field".to_string())
    }
    fn str32(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| "non-UTF-8 string field".to_string())
    }
    fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.u32()? as usize;
        // The count must fit in the remaining body: rejects a garbled
        // count before it becomes a giant allocation.
        if n.saturating_mul(4) > self.b.len() - self.pos {
            return Err(format!("f32 vector count {n} exceeds remaining body"));
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(f32::from_bits(self.u32()?));
        }
        Ok(v)
    }
    fn done(&self) -> Result<(), String> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(format!(
                "{} trailing bytes after frame body",
                self.b.len() - self.pos
            ))
        }
    }
}

/// A frame as it exists on the wire: tag + raw body, not yet decoded.
/// The connection layer reads these so deterministic `garble` faults can
/// corrupt bytes *before* [`decode`] sees them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RawFrame {
    pub tag: u8,
    pub payload: Vec<u8>,
}

/// Encode a frame into its raw wire form.
pub fn encode(frame: &Frame) -> RawFrame {
    let mut e = Enc::new();
    let tag = match frame {
        Frame::Request {
            id,
            session,
            hidden,
            deadline_ms,
            attempt,
            model,
            seq_len,
            payload,
        } => {
            e.u64(*id);
            let mut flags = 0u8;
            if session.is_some() {
                flags |= 1;
            }
            if hidden.is_some() {
                flags |= 2;
            }
            if deadline_ms.is_some() {
                flags |= 4;
            }
            if model.is_some() {
                flags |= 8;
            }
            e.u8(flags);
            if let Some(s) = session {
                e.u64(*s);
            }
            if let Some(h) = hidden {
                e.u32(*h);
            }
            if let Some(d) = deadline_ms {
                e.u32(*d);
            }
            e.u16(*attempt);
            if let Some(m) = model {
                e.str16(m);
            }
            e.u32(*seq_len);
            e.f32s(payload);
            TAG_REQUEST
        }
        Frame::Begin { session, hidden } => {
            e.u64(*session);
            e.u8(u8::from(hidden.is_some()));
            if let Some(h) = hidden {
                e.u32(*h);
            }
            TAG_BEGIN
        }
        Frame::End { session } => {
            e.u64(*session);
            TAG_END
        }
        Frame::Control { body } => {
            e.str32(body);
            TAG_CONTROL
        }
        Frame::Response {
            id,
            session_steps,
            latency_us,
            batch,
            h_t,
        } => {
            e.u64(*id);
            e.u8(u8::from(session_steps.is_some()));
            if let Some(s) = session_steps {
                e.u64(*s);
            }
            e.u64(*latency_us);
            e.u32(*batch);
            e.f32s(h_t);
            TAG_RESPONSE
        }
        Frame::Error { id, err } => {
            e.u64(*id);
            e.u8(err.code());
            e.u8(u8::from(err.retryable()));
            let (a, b, detail) = match err {
                WireError::Sharp(SharpError::Rejected(m)) => (0, 0, m.as_str()),
                WireError::Sharp(SharpError::ExecFailed(m)) => (0, 0, m.as_str()),
                WireError::Sharp(SharpError::DeadlineExceeded { waited_ms }) => {
                    (*waited_ms, 0, "")
                }
                WireError::Sharp(SharpError::Overloaded { depth, watermark }) => {
                    (*depth as u64, *watermark as u64, "")
                }
                WireError::Sharp(SharpError::WorkerFailed { worker, reason }) => {
                    // a = worker index + 1 (0 encodes `None`).
                    (worker.map_or(0, |w| w as u64 + 1), 0, reason.as_str())
                }
                WireError::Malformed(m) => (0, 0, m.as_str()),
                WireError::TooLarge { size, max } => (*size, *max, ""),
                WireError::Draining => (0, 0, ""),
            };
            e.u64(a);
            e.u64(b);
            e.str32(detail);
            TAG_ERROR
        }
        Frame::Begun { session } => {
            e.u64(*session);
            TAG_BEGUN
        }
        Frame::Ended { session, state } => {
            e.u64(*session);
            e.u8(u8::from(state.is_some()));
            if let Some((steps, h, c)) = state {
                e.u64(*steps);
                e.f32s(h);
                e.f32s(c);
            }
            TAG_ENDED
        }
        Frame::ControlReply { body } => {
            e.str32(body);
            TAG_CONTROL_REPLY
        }
    };
    RawFrame {
        tag,
        payload: e.buf,
    }
}

/// Decode a raw frame body. Any structural defect — unknown tag,
/// truncated field, bogus vector count, trailing bytes — is an `Err`
/// with a human-readable cause (the connection layer wraps it in
/// [`WireError::Malformed`]).
pub fn decode(raw: &RawFrame) -> Result<Frame, String> {
    let mut d = Dec::new(&raw.payload);
    let frame = match raw.tag {
        TAG_REQUEST => {
            let id = d.u64()?;
            let flags = d.u8()?;
            let session = if flags & 1 != 0 { Some(d.u64()?) } else { None };
            let hidden = if flags & 2 != 0 { Some(d.u32()?) } else { None };
            let deadline_ms = if flags & 4 != 0 { Some(d.u32()?) } else { None };
            let attempt = d.u16()?;
            let model = if flags & 8 != 0 { Some(d.str16()?) } else { None };
            let seq_len = d.u32()?;
            let payload = d.f32s()?;
            Frame::Request {
                id,
                session,
                hidden,
                deadline_ms,
                attempt,
                model,
                seq_len,
                payload,
            }
        }
        TAG_BEGIN => {
            let session = d.u64()?;
            let has_hidden = d.u8()?;
            let hidden = if has_hidden != 0 { Some(d.u32()?) } else { None };
            Frame::Begin { session, hidden }
        }
        TAG_END => Frame::End { session: d.u64()? },
        TAG_CONTROL => Frame::Control { body: d.str32()? },
        TAG_RESPONSE => {
            let id = d.u64()?;
            let has_steps = d.u8()?;
            let session_steps = if has_steps != 0 { Some(d.u64()?) } else { None };
            let latency_us = d.u64()?;
            let batch = d.u32()?;
            let h_t = d.f32s()?;
            Frame::Response {
                id,
                session_steps,
                latency_us,
                batch,
                h_t,
            }
        }
        TAG_ERROR => {
            let id = d.u64()?;
            let code = d.u8()?;
            let _retryable = d.u8()?; // recomputed from the code below
            let a = d.u64()?;
            let b = d.u64()?;
            let detail = d.str32()?;
            let err = match code {
                CODE_REJECTED => WireError::Sharp(SharpError::Rejected(detail)),
                CODE_EXEC_FAILED => WireError::Sharp(SharpError::ExecFailed(detail)),
                CODE_DEADLINE => WireError::Sharp(SharpError::DeadlineExceeded { waited_ms: a }),
                CODE_OVERLOADED => WireError::Sharp(SharpError::Overloaded {
                    depth: a as usize,
                    watermark: b as usize,
                }),
                CODE_WORKER_FAILED => WireError::Sharp(SharpError::WorkerFailed {
                    worker: if a == 0 { None } else { Some(a as usize - 1) },
                    reason: detail,
                }),
                CODE_MALFORMED => WireError::Malformed(detail),
                CODE_TOO_LARGE => WireError::TooLarge { size: a, max: b },
                CODE_DRAINING => WireError::Draining,
                other => return Err(format!("unknown wire-error code {other}")),
            };
            Frame::Error { id, err }
        }
        TAG_BEGUN => Frame::Begun { session: d.u64()? },
        TAG_ENDED => {
            let session = d.u64()?;
            let had_state = d.u8()?;
            let state = if had_state != 0 {
                let steps = d.u64()?;
                let h = d.f32s()?;
                let c = d.f32s()?;
                Some((steps, h, c))
            } else {
                None
            };
            Frame::Ended { session, state }
        }
        TAG_CONTROL_REPLY => Frame::ControlReply { body: d.str32()? },
        other => return Err(format!("unknown frame tag 0x{other:02x}")),
    };
    d.done()?;
    Ok(frame)
}

/// Deterministically corrupt a raw frame in place — the `garble` network
/// fault. Flipping the type tag guarantees [`decode`] rejects the frame
/// as malformed (reserved tag space), which is what makes the chaos test
/// reproducible: a payload-byte flip could decode to different-but-valid
/// floats and slip through.
pub fn garble(raw: &mut RawFrame) {
    raw.tag ^= 0x40;
    if let Some(b) = raw.payload.first_mut() {
        *b ^= 0xA5;
    }
}

// ---------------------------------------------------------------------
// Framed IO
// ---------------------------------------------------------------------

/// Outcome of reading one raw frame from a stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RawOutcome {
    /// A complete frame (tag + body) was read.
    Frame(RawFrame),
    /// The declared body length exceeds the cap. The body was NOT read
    /// (the stream is out of sync): reply with a typed error and close.
    TooLarge { size: u64, max: u64 },
    /// Clean EOF at a frame boundary: the peer closed deliberately.
    /// Mid-frame EOF surfaces as `UnexpectedEof` instead.
    Eof,
}

/// Read one raw frame. Timeouts and resets propagate as `io::Error`
/// (kind `WouldBlock`/`TimedOut` under a socket read deadline) — the
/// connection layer maps them onto its slowloris/idle policy.
pub fn read_raw(r: &mut impl Read, max_frame: usize) -> std::io::Result<RawOutcome> {
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(RawOutcome::Eof),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    read_raw_after(first[0], r, max_frame)
}

/// [`read_raw`] when the first length byte was already consumed (the
/// connection loop reads it separately so idle-waiting and mid-frame
/// timeouts are distinguishable: a timeout before any byte is idleness,
/// a timeout after this call started is a slow or stalled peer).
pub fn read_raw_after(
    first: u8,
    r: &mut impl Read,
    max_frame: usize,
) -> std::io::Result<RawOutcome> {
    let mut rest = [0u8; 3];
    r.read_exact(&mut rest)?;
    let len = u32::from_be_bytes([first, rest[0], rest[1], rest[2]]) as usize;
    if len > max_frame {
        return Ok(RawOutcome::TooLarge {
            size: len as u64,
            max: max_frame as u64,
        });
    }
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(RawOutcome::Frame(RawFrame {
        tag: tag[0],
        payload,
    }))
}

/// Write one raw frame (`len`-prefix, tag, body) and flush.
pub fn write_raw(w: &mut impl Write, raw: &RawFrame) -> std::io::Result<()> {
    w.write_all(&(raw.payload.len() as u32).to_be_bytes())?;
    w.write_all(&[raw.tag])?;
    w.write_all(&raw.payload)?;
    w.flush()
}

/// Encode and write one frame.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> std::io::Result<()> {
    write_raw(w, &encode(frame))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(frame: Frame) {
        let raw = encode(&frame);
        let back = decode(&raw).expect("decode");
        assert_eq!(back, frame);
        // And through a byte stream.
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let mut cursor = &buf[..];
        match read_raw(&mut cursor, DEFAULT_MAX_FRAME).unwrap() {
            RawOutcome::Frame(r) => assert_eq!(decode(&r).unwrap(), frame),
            other => panic!("expected frame, got {other:?}"),
        }
        assert!(cursor.is_empty(), "stream consumed exactly");
    }

    #[test]
    fn request_roundtrips_all_field_combinations() {
        roundtrip(Frame::Request {
            id: 7,
            session: None,
            hidden: None,
            deadline_ms: None,
            attempt: 0,
            model: None,
            seq_len: 4,
            payload: vec![1.0, -2.5, f32::MIN_POSITIVE, 0.0],
        });
        roundtrip(Frame::Request {
            id: u64::MAX,
            session: Some(42),
            hidden: Some(320),
            deadline_ms: Some(250),
            attempt: 3,
            model: Some("stack3_h256_t16_b4".to_string()),
            seq_len: 8,
            payload: vec![0.125; 64],
        });
    }

    #[test]
    fn session_and_control_frames_roundtrip() {
        roundtrip(Frame::Begin {
            session: 9,
            hidden: Some(64),
        });
        roundtrip(Frame::Begin {
            session: 9,
            hidden: None,
        });
        roundtrip(Frame::End { session: 9 });
        roundtrip(Frame::Begun { session: 9 });
        roundtrip(Frame::Ended {
            session: 9,
            state: None,
        });
        roundtrip(Frame::Ended {
            session: 9,
            state: Some((17, vec![0.5, -0.5], vec![1.5, 2.5])),
        });
        roundtrip(Frame::Control {
            body: r#"{"cmd":"drain"}"#.to_string(),
        });
        roundtrip(Frame::ControlReply {
            body: r#"{"ok":true}"#.to_string(),
        });
    }

    #[test]
    fn response_roundtrips_with_exact_bits() {
        // Denormals, negative zero, and extremes must survive the wire
        // bit-for-bit — the reconnect-resume bit-compare depends on it.
        let h_t = vec![
            f32::from_bits(0x0000_0001), // smallest denormal
            -0.0,
            f32::MAX,
            f32::MIN,
            1.0e-40,
        ];
        let frame = Frame::Response {
            id: 3,
            session_steps: Some(5),
            latency_us: 1234,
            batch: 4,
            h_t: h_t.clone(),
        };
        let raw = encode(&frame);
        match decode(&raw).unwrap() {
            Frame::Response { h_t: got, .. } => {
                let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
                let wb: Vec<u32> = h_t.iter().map(|x| x.to_bits()).collect();
                assert_eq!(gb, wb, "bit-exact across the wire");
            }
            other => panic!("wrong frame: {other:?}"),
        }
        roundtrip(Frame::Response {
            id: 3,
            session_steps: None,
            latency_us: 0,
            batch: 1,
            h_t: vec![],
        });
    }

    #[test]
    fn every_wire_error_roundtrips_losslessly() {
        let cases = vec![
            WireError::Sharp(SharpError::Rejected("bad shape".into())),
            WireError::Sharp(SharpError::ExecFailed("kernel blew up".into())),
            WireError::Sharp(SharpError::DeadlineExceeded { waited_ms: 77 }),
            WireError::Sharp(SharpError::Overloaded {
                depth: 12,
                watermark: 8,
            }),
            WireError::Sharp(SharpError::WorkerFailed {
                worker: Some(2),
                reason: "panicked".into(),
            }),
            WireError::Sharp(SharpError::WorkerFailed {
                worker: None,
                reason: "reply channel closed".into(),
            }),
            WireError::Malformed("unknown frame tag 0x41".into()),
            WireError::TooLarge {
                size: 1 << 30,
                max: 16 << 20,
            },
            WireError::Draining,
        ];
        for err in cases {
            let frame = Frame::Error {
                id: 11,
                err: err.clone(),
            };
            let raw = encode(&frame);
            // Byte 10 of the body is the on-wire retryable flag; it must
            // agree with the recomputed classification.
            assert_eq!(raw.payload[9], u8::from(err.retryable()), "{err}");
            match decode(&raw).unwrap() {
                Frame::Error { id, err: back } => {
                    assert_eq!(id, 11);
                    assert_eq!(back, err);
                    assert_eq!(back.retryable(), err.retryable());
                }
                other => panic!("wrong frame: {other:?}"),
            }
        }
    }

    #[test]
    fn retryable_classification_matches_the_issue() {
        assert!(WireError::Sharp(SharpError::Overloaded {
            depth: 9,
            watermark: 8
        })
        .retryable());
        assert!(WireError::Sharp(SharpError::WorkerFailed {
            worker: None,
            reason: "x".into()
        })
        .retryable());
        assert!(WireError::Draining.retryable());
        assert!(!WireError::Sharp(SharpError::Rejected("x".into())).retryable());
        assert!(!WireError::Sharp(SharpError::DeadlineExceeded { waited_ms: 1 }).retryable());
        assert!(!WireError::Malformed("x".into()).retryable());
        assert!(!WireError::TooLarge { size: 2, max: 1 }.retryable());
    }

    #[test]
    fn oversized_frame_is_rejected_before_allocation() {
        // Header declares 1 GiB; only the 4-byte header is on the wire.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(1u32 << 30).to_be_bytes());
        let mut cursor = &buf[..];
        match read_raw(&mut cursor, 1024).unwrap() {
            RawOutcome::TooLarge { size, max } => {
                assert_eq!(size, 1 << 30);
                assert_eq!(max, 1024);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn eof_at_boundary_is_clean_but_midframe_is_an_error() {
        let mut empty: &[u8] = &[];
        assert_eq!(read_raw(&mut empty, 1024).unwrap(), RawOutcome::Eof);

        // Length header promises 8 payload bytes; the stream dies early.
        let mut buf = Vec::new();
        buf.extend_from_slice(&8u32.to_be_bytes());
        buf.push(TAG_END);
        buf.extend_from_slice(&[0, 0, 0]); // 3 of the promised 8
        let mut cursor = &buf[..];
        let err = read_raw(&mut cursor, 1024).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn malformed_bodies_fail_with_causes() {
        // Unknown tag.
        let raw = RawFrame {
            tag: 0x41,
            payload: vec![],
        };
        assert!(decode(&raw).unwrap_err().contains("unknown frame tag"));

        // Truncated request body.
        let mut good = encode(&Frame::Request {
            id: 1,
            session: Some(2),
            hidden: None,
            deadline_ms: None,
            attempt: 0,
            model: None,
            seq_len: 2,
            payload: vec![1.0, 2.0],
        });
        good.payload.truncate(9); // id + flags only
        assert!(decode(&good).unwrap_err().contains("truncated"));

        // Bogus vector count (claims more f32s than the body holds).
        let mut e = Enc::new();
        e.u64(1); // id
        e.u8(0); // flags
        e.u16(0); // attempt
        e.u32(2); // seq_len
        e.u32(1_000_000); // count: lies
        let raw = RawFrame {
            tag: TAG_REQUEST,
            payload: e.buf,
        };
        assert!(decode(&raw).unwrap_err().contains("exceeds remaining body"));

        // Trailing junk after a valid body.
        let mut raw = encode(&Frame::End { session: 5 });
        raw.payload.push(0xFF);
        assert!(decode(&raw).unwrap_err().contains("trailing"));
    }

    #[test]
    fn garble_guarantees_a_deterministic_malformed_verdict() {
        let mut raw = encode(&Frame::Request {
            id: 1,
            session: None,
            hidden: None,
            deadline_ms: None,
            attempt: 0,
            model: None,
            seq_len: 1,
            payload: vec![1.0],
        });
        let pristine = raw.clone();
        garble(&mut raw);
        assert_ne!(raw, pristine);
        assert!(decode(&raw).is_err(), "garbled frame must not decode");
        // Determinism: garbling the same frame twice yields the same bytes.
        let mut again = pristine.clone();
        garble(&mut again);
        assert_eq!(raw, again);
    }
}
