//! Request/response types flowing through the coordinator.

/// One inference request: a (seq_len x input_dim) payload plus metadata.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    /// Optional streaming-session key: requests with the same session
    /// carry recurrent state across calls and always route to the same
    /// worker (session affinity).
    pub session: Option<u64>,
    /// Which hidden dim (model variant) to serve this on, when the
    /// server hosts several at once. `None` resolves automatically: the
    /// only served dim, or the one matching the payload width.
    pub hidden: Option<usize>,
    /// Target a stacked artifact by manifest name (entries carrying
    /// `layers`/`bidirectional`/`P`). Stacked models bind a different
    /// executable per name and are NOT width-routable (deep stacks share
    /// D with flat models), so they are addressed explicitly. `None` =
    /// the flat single-layer buckets.
    pub model: Option<String>,
    pub seq_len: usize,
    /// Row-major (seq_len, input_dim).
    pub payload: Vec<f32>,
    /// Wall-clock enqueue instant (set by the server).
    pub enqueued_at: std::time::Instant,
    /// Latency budget measured from `enqueued_at`. Once it elapses the
    /// request resolves with `SharpError::DeadlineExceeded` instead of
    /// waiting: workers shed it at dequeue, and `Server::try_infer`
    /// stops waiting client-side. `None` = wait forever (the pre-fault-
    /// tolerance behavior, and the zero-overhead fast path).
    pub deadline: Option<std::time::Duration>,
}

impl InferenceRequest {
    pub fn new(id: u64, seq_len: usize, payload: Vec<f32>) -> Self {
        InferenceRequest {
            id,
            session: None,
            hidden: None,
            model: None,
            seq_len,
            payload,
            enqueued_at: std::time::Instant::now(),
            deadline: None,
        }
    }

    pub fn with_session(mut self, session: u64) -> Self {
        self.session = Some(session);
        self
    }

    pub fn with_hidden(mut self, hidden: usize) -> Self {
        self.hidden = Some(hidden);
        self
    }

    /// Target a stacked artifact by name (see [`Self::model`]).
    pub fn with_model(mut self, name: impl Into<String>) -> Self {
        self.model = Some(name.into());
        self
    }

    /// Give this request a latency budget (see [`Self::deadline`]).
    pub fn with_deadline(mut self, deadline: std::time::Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// True once the deadline (if any) has elapsed.
    pub fn expired(&self) -> bool {
        match self.deadline {
            Some(d) => self.enqueued_at.elapsed() >= d,
            None => false,
        }
    }

    /// Time left on the deadline: `None` = unbounded, `Some(0)` = past
    /// due. Used by `Server::try_infer` as its `recv_timeout` budget.
    pub fn remaining(&self) -> Option<std::time::Duration> {
        self.deadline
            .map(|d| d.saturating_sub(self.enqueued_at.elapsed()))
    }
}

/// The response: final hidden state plus timing.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// Final hidden state (H) — for session chunks, the state at the
    /// chunk's last frame (the carry persisted for the next chunk).
    pub h_t: Vec<f32>,
    /// End-to-end latency through the coordinator, seconds.
    pub latency_s: f64,
    /// Batch size this request was served in. For session chunks this
    /// is the fused window's lane count — how many concurrent sessions
    /// shared each recurrent step's GEMM (1 = the degenerate solo
    /// window; fusion never changes the bits either way).
    pub batch_size: usize,
    /// The SHARP cycle-simulator's accelerator-time estimate, seconds
    /// (what the modeled ASIC would have taken for this request).
    pub accel_time_s: f64,
    /// For session chunks: the session's chunk count AFTER this one.
    /// Streaming clients use it to detect a carry restart — if the
    /// session was LRU-evicted mid-stream, the count resets to 1 instead
    /// of continuing, so a client sending chunk N can notice N != steps.
    /// `None` for stateless requests.
    pub session_steps: Option<u64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder() {
        let r = InferenceRequest::new(7, 4, vec![0.0; 16])
            .with_session(42)
            .with_hidden(256)
            .with_model("stack3_h256_t16_b4");
        assert_eq!(r.id, 7);
        assert_eq!(r.session, Some(42));
        assert_eq!(r.hidden, Some(256));
        assert_eq!(r.model.as_deref(), Some("stack3_h256_t16_b4"));
        assert_eq!(r.payload.len(), 16);
        assert_eq!(r.deadline, None);
        assert!(!r.expired());
        assert_eq!(r.remaining(), None);
    }

    #[test]
    fn deadline_expiry_and_remaining() {
        use std::time::Duration;
        let r = InferenceRequest::new(1, 1, vec![0.0]).with_deadline(Duration::from_secs(3600));
        assert!(!r.expired());
        assert!(r.remaining().unwrap() > Duration::from_secs(3500));

        let mut past = InferenceRequest::new(2, 1, vec![0.0]).with_deadline(Duration::ZERO);
        past.enqueued_at = std::time::Instant::now() - Duration::from_millis(10);
        assert!(past.expired());
        assert_eq!(past.remaining(), Some(Duration::ZERO));
    }
}
