//! Request/response types flowing through the coordinator.

/// One inference request: a (seq_len x input_dim) payload plus metadata.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    /// Optional streaming-session key: requests with the same session
    /// carry recurrent state across calls (cell artifacts).
    pub session: Option<u64>,
    pub seq_len: usize,
    /// Row-major (seq_len, input_dim).
    pub payload: Vec<f32>,
    /// Wall-clock enqueue instant (set by the server).
    pub enqueued_at: std::time::Instant,
}

impl InferenceRequest {
    pub fn new(id: u64, seq_len: usize, payload: Vec<f32>) -> Self {
        InferenceRequest {
            id,
            session: None,
            seq_len,
            payload,
            enqueued_at: std::time::Instant::now(),
        }
    }

    pub fn with_session(mut self, session: u64) -> Self {
        self.session = Some(session);
        self
    }
}

/// The response: final hidden state plus timing.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// Final hidden state (H).
    pub h_t: Vec<f32>,
    /// End-to-end latency through the coordinator, seconds.
    pub latency_s: f64,
    /// Batch size this request was served in.
    pub batch_size: usize,
    /// The SHARP cycle-simulator's accelerator-time estimate, seconds
    /// (what the modeled ASIC would have taken for this request).
    pub accel_time_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder() {
        let r = InferenceRequest::new(7, 4, vec![0.0; 16]).with_session(42);
        assert_eq!(r.id, 7);
        assert_eq!(r.session, Some(42));
        assert_eq!(r.payload.len(), 16);
    }
}
