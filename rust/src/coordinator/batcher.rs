//! Dynamic batcher: groups compatible requests (same bucket) up to a size
//! or time bound — the standard SLA-aware online-inference tradeoff the
//! paper's intro describes (larger batches raise utilization, the latency
//! SLA caps how long we may wait).

use std::time::{Duration, Instant};

use super::request::InferenceRequest;

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Maximum requests per batch (bounded by the artifact's B bucket).
    pub max_batch: usize,
    /// Maximum time the oldest request may wait before the batch closes.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Accumulates requests into batches under the policy.
#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherConfig,
    pending: Vec<InferenceRequest>,
    oldest_at: Option<Instant>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher {
            cfg,
            pending: Vec::new(),
            oldest_at: None,
        }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// The live policy.
    pub fn cfg(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Swap the policy at runtime (the adaptive controller's write path).
    /// Pending requests are untouched; the next `push`/`poll` sees the
    /// new bounds, so a shrunken `max_batch` closes on the next push and
    /// a shortened `max_wait` fires on the next poll.
    pub fn set_cfg(&mut self, cfg: BatcherConfig) {
        self.cfg = cfg;
    }

    /// Add a request; returns a closed batch if the size bound is hit.
    pub fn push(&mut self, req: InferenceRequest) -> Option<Vec<InferenceRequest>> {
        if self.pending.is_empty() {
            self.oldest_at = Some(Instant::now());
        }
        self.pending.push(req);
        if self.pending.len() >= self.cfg.max_batch {
            return self.take();
        }
        None
    }

    /// Close the batch if the oldest member has waited past the bound.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<InferenceRequest>> {
        match self.oldest_at {
            Some(t0) if now.duration_since(t0) >= self.cfg.max_wait && !self.pending.is_empty() => {
                self.take()
            }
            _ => None,
        }
    }

    /// Force-close whatever is pending (drain on shutdown).
    pub fn take(&mut self) -> Option<Vec<InferenceRequest>> {
        if self.pending.is_empty() {
            return None;
        }
        self.oldest_at = None;
        Some(std::mem::take(&mut self.pending))
    }

    /// How long until the wait bound expires (for the worker's park time).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest_at
            .map(|t0| self.cfg.max_wait.saturating_sub(now.duration_since(t0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, 4, vec![0.0; 8])
    }

    #[test]
    fn closes_on_size_bound() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        assert!(b.push(req(0)).is_none());
        assert!(b.push(req(1)).is_none());
        let batch = b.push(req(2)).expect("size bound");
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn closes_on_time_bound() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(1),
        });
        b.push(req(0));
        assert!(b.poll(Instant::now()).is_none()); // too early
        let later = Instant::now() + Duration::from_millis(5);
        let batch = b.poll(later).expect("time bound");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn never_drops_never_duplicates_preserves_order() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
        });
        let mut out = Vec::new();
        for i in 0..103u64 {
            if let Some(batch) = b.push(req(i)) {
                out.extend(batch.into_iter().map(|r| r.id));
            }
        }
        if let Some(batch) = b.take() {
            out.extend(batch.into_iter().map(|r| r.id));
        }
        assert_eq!(out, (0..103).collect::<Vec<_>>());
    }

    #[test]
    fn runtime_policy_swap_applies_to_next_push() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
        });
        b.push(req(0));
        b.push(req(1));
        // Shrink max_batch below the pending count: the next push closes.
        b.set_cfg(BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        assert_eq!(b.cfg().max_batch, 2);
        let batch = b.push(req(2)).expect("shrunken bound closes");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn empty_take_is_none() {
        let mut b = Batcher::new(BatcherConfig::default());
        assert!(b.take().is_none());
        assert!(b.poll(Instant::now()).is_none());
    }
}
