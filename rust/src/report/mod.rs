//! Report rendering: every experiment returns typed rows plus a rendered
//! text table; this module carries shared formatting and the EXPERIMENTS
//! summary writer so the CLI and `benches/` print identical output.

use crate::util::table::Table;

/// A rendered exhibit (one paper table or figure).
#[derive(Debug, Clone)]
pub struct Exhibit {
    /// Paper exhibit id, e.g. "fig09", "table4".
    pub id: &'static str,
    /// Paper caption summary.
    pub title: &'static str,
    /// Rendered rows (what the paper's chart/table shows).
    pub tables: Vec<Table>,
    /// Shape-fidelity notes: what should hold vs. the paper.
    pub notes: Vec<String>,
}

impl Exhibit {
    pub fn render(&self) -> String {
        let mut out = format!("###### {} — {} ######\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.render());
            out.push('\n');
        }
        if !self.notes.is_empty() {
            out.push_str("notes:\n");
            for n in &self.notes {
                out.push_str(&format!("  - {n}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_title_tables_and_notes() {
        let mut t = Table::new("t").header(&["a"]);
        t.row(&["1"]);
        let e = Exhibit {
            id: "fig00",
            title: "demo",
            tables: vec![t],
            notes: vec!["shape holds".into()],
        };
        let s = e.render();
        assert!(s.contains("fig00"));
        assert!(s.contains("shape holds"));
    }
}
