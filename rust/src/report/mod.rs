//! Report rendering: every experiment returns typed rows plus a rendered
//! text table; this module carries shared formatting, the EXPERIMENTS
//! summary writer ([`summary`], what `sharp all` appends after the
//! exhibits), and the JSON emitter ([`Exhibit::to_json`], what
//! `sharp all --json <dir>` writes) so the CLI and `benches/` print
//! identical output.

use crate::util::json::Json;
use crate::util::table::Table;

/// A rendered exhibit (one paper table or figure).
#[derive(Debug, Clone)]
pub struct Exhibit {
    /// Paper exhibit id, e.g. "fig09", "table4".
    pub id: &'static str,
    /// Paper caption summary.
    pub title: &'static str,
    /// Rendered rows (what the paper's chart/table shows).
    pub tables: Vec<Table>,
    /// Shape-fidelity notes: what should hold vs. the paper.
    pub notes: Vec<String>,
}

impl Exhibit {
    pub fn render(&self) -> String {
        let mut out = format!("###### {} — {} ######\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.render());
            out.push('\n');
        }
        if !self.notes.is_empty() {
            out.push_str("notes:\n");
            for n in &self.notes {
                out.push_str(&format!("  - {n}\n"));
            }
        }
        out
    }

    /// Machine-readable form of the exhibit (what `sharp all --json <dir>`
    /// writes, one file per exhibit).
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("id".into(), Json::Str(self.id.to_string()));
        obj.insert("title".into(), Json::Str(self.title.to_string()));
        obj.insert(
            "notes".into(),
            Json::Arr(self.notes.iter().map(|n| Json::Str(n.clone())).collect()),
        );
        let tables = self
            .tables
            .iter()
            .map(|t| {
                let mut tj = std::collections::BTreeMap::new();
                tj.insert("title".into(), Json::Str(t.title().to_string()));
                tj.insert(
                    "header".into(),
                    Json::Arr(
                        t.header_cells()
                            .iter()
                            .map(|c| Json::Str(c.clone()))
                            .collect(),
                    ),
                );
                tj.insert(
                    "rows".into(),
                    Json::Arr(
                        t.data_rows()
                            .iter()
                            .map(|r| {
                                Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect())
                            })
                            .collect(),
                    ),
                );
                Json::Obj(tj)
            })
            .collect();
        obj.insert("tables".into(), Json::Arr(tables));
        Json::Obj(obj)
    }
}

/// The EXPERIMENTS summary: one row per exhibit (id, title, table/row
/// counts, first shape-fidelity note). `sharp all` prints it after the
/// exhibits; EXPERIMENTS.md's paper-vs-measured table is this output.
pub fn summary(exhibits: &[Exhibit]) -> String {
    let mut t = Table::new("EXPERIMENTS summary (paper exhibit -> measured shape)")
        .header(&["id", "title", "tables", "rows", "shape-fidelity note"]);
    for e in exhibits {
        let rows: usize = e.tables.iter().map(Table::n_rows).sum();
        t.row(&[
            e.id.to_string(),
            e.title.to_string(),
            e.tables.len().to_string(),
            rows.to_string(),
            e.notes.first().cloned().unwrap_or_default(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_title_tables_and_notes() {
        let mut t = Table::new("t").header(&["a"]);
        t.row(&["1"]);
        let e = Exhibit {
            id: "fig00",
            title: "demo",
            tables: vec![t],
            notes: vec!["shape holds".into()],
        };
        let s = e.render();
        assert!(s.contains("fig00"));
        assert!(s.contains("shape holds"));
    }

    #[test]
    fn summary_one_line_per_exhibit() {
        let mk = |id: &'static str| {
            let mut t = Table::new("t").header(&["a"]);
            t.row(&["1"]);
            Exhibit {
                id,
                title: "demo",
                tables: vec![t],
                notes: vec!["note".into()],
            }
        };
        let s = summary(&[mk("fig01"), mk("table2")]);
        assert!(s.contains("fig01"));
        assert!(s.contains("table2"));
        assert!(s.contains("EXPERIMENTS summary"));
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let mut t = Table::new("t").header(&["a", "b"]);
        t.row(&["1", "x"]);
        let e = Exhibit {
            id: "fig00",
            title: "demo",
            tables: vec![t],
            notes: vec![],
        };
        let text = crate::util::json::write(&e.to_json());
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("id").unwrap().as_str(), Some("fig00"));
        let tables = v.get("tables").unwrap().as_arr().unwrap();
        assert_eq!(tables.len(), 1);
        assert_eq!(
            tables[0].get("rows").unwrap().as_arr().unwrap().len(),
            1
        );
    }
}
