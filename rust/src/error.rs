//! Minimal error-context machinery (the offline registry has no `anyhow`).
//!
//! API-compatible with the `anyhow` subset this crate uses: an opaque
//! [`Error`] that records a chain of context messages, a [`Result`] alias,
//! a [`Context`] extension trait (`.context()` / `.with_context()`), and
//! the [`anyhow!`](crate::anyhow), [`bail!`](crate::bail), and
//! [`ensure!`](crate::ensure) macros.
//!
//! Formatting follows `anyhow`'s convention: `{}` prints the outermost
//! message only; `{:#}` prints the whole chain, outermost first, joined
//! with `": "` — which is what every caller that surfaces errors to users
//! (`{e:#}`) relies on.

use std::fmt;

/// An opaque error: a chain of messages, outermost context first.
///
/// Deliberately does **not** implement `std::error::Error`, so the blanket
/// `From<E: std::error::Error>` conversion below is coherent (the same
/// trick `anyhow::Error` uses).
pub struct Error {
    /// chain[0] is the outermost context; the last entry is the root cause.
    chain: Vec<String>,
}

/// Crate-wide result alias (mirrors `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Like anyhow: the message, then the causes.
        write!(f, "{}", self.message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts by flattening its `source()` chain.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding context to fallible results.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any value
/// that converts into one (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::error::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::error::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds (mirrors
/// `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

// Make the macros importable as `use crate::error::{anyhow, bail, ensure}`
// (and `use sharp::error::...` from bins/tests/examples), matching how
// callers previously imported them from the `anyhow` crate.
pub use crate::{anyhow, bail, ensure};

/// Typed serving errors crossing the coordinator's reply channels.
///
/// Unlike the opaque [`Error`] chain (which is for operator-facing
/// diagnostics), these are *protocol*: a client under a deadline or an
/// overload policy dispatches on the variant, not on a message string.
/// `Rejected`/`ExecFailed` carry the same human-readable detail the
/// reply channels used to ship as bare `String`s.
///
/// `SharpError` implements `std::error::Error`, so the blanket
/// `From<E: std::error::Error>` above converts it into the crate
/// [`Error`] wherever a `Result<T>` surface (e.g. `Server::infer`)
/// flattens it back into a message chain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SharpError {
    /// The request was invalid (shape, unknown model, zero frames, ...)
    /// and was never executed.
    Rejected(String),
    /// Execution started on a worker and failed.
    ExecFailed(String),
    /// The request's deadline elapsed before a reply: shed at worker
    /// dequeue (never executed) or timed out client-side in
    /// `Server::try_infer` (the reply, if any, was dropped unread).
    DeadlineExceeded {
        /// How long the request had waited when the deadline fired.
        waited_ms: u64,
    },
    /// Shed at admission by the `--overload shed` policy: the pool's
    /// queue depth was at or past the watermark.
    Overloaded { depth: usize, watermark: usize },
    /// A worker replica died (panic) or was torn down with the request
    /// in flight. `worker` is `None` when the failure is only visible
    /// client-side (the reply channel closed without a verdict).
    WorkerFailed {
        worker: Option<usize>,
        reason: String,
    },
}

impl fmt::Display for SharpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SharpError::Rejected(msg) => write!(f, "rejected: {msg}"),
            SharpError::ExecFailed(msg) => write!(f, "execution failed: {msg}"),
            SharpError::DeadlineExceeded { waited_ms } => {
                write!(f, "deadline exceeded after {waited_ms} ms")
            }
            SharpError::Overloaded { depth, watermark } => {
                write!(f, "overloaded: queue depth {depth} >= watermark {watermark}")
            }
            SharpError::WorkerFailed { worker, reason } => match worker {
                Some(w) => write!(f, "worker {w} failed: {reason}"),
                None => write!(f, "worker failed: {reason}"),
            },
        }
    }
}

impl std::error::Error for SharpError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_plain_vs_alternate() {
        let e: Error = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file missing");
    }

    #[test]
    fn context_trait_on_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert!(format!("{e:#}").starts_with("outer: "));

        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("opening {}", "x.json")).unwrap_err();
        assert_eq!(format!("{e:#}"), "opening x.json: file missing");
    }

    #[test]
    fn macros_build_errors() {
        let name = "fig99";
        let e = anyhow!("unknown exhibit '{name}'");
        assert_eq!(format!("{e}"), "unknown exhibit 'fig99'");
        let e2 = anyhow!(String::from("plain message"));
        assert_eq!(format!("{e2}"), "plain message");
        let e3 = anyhow!("two part: {}", 42);
        assert_eq!(format!("{e3}"), "two part: 42");
    }

    #[test]
    fn bail_and_ensure_return_early() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable when flag is false")
        }
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
        assert_eq!(
            format!("{}", f(true).unwrap_err()),
            "unreachable when flag is false"
        );
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::from(io_err()).context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("file missing"));
    }
}
