//! Configuration types: the accelerator design point (paper Table 1) and
//! the LSTM model geometry (paper Table 5 / Fig. 9 sweeps).

pub mod accel;
pub mod model;
pub mod presets;

pub use accel::{SharpConfig, VsMapping};
pub use model::{CellKind, Direction, LstmConfig};
