//! The SHARP design point (paper Table 1) and its derived quantities.

use crate::util::ceil_div;

/// How the N vector-scalar units are laid over the weight matrix (Fig. 7).
///
/// Each VS unit multiplies one input/hidden scalar by `k` *rows* of one
/// weight-matrix column. Mapping units "column-wise" spreads them over the
/// contraction dimension (their partial vectors are then summed by the
/// R-Add-Reduce tree); stacking "row-wise" widens the output coverage
/// instead. `row_groups` counts the row-wise stacks: Config1 of Fig. 7 is
/// `row_groups = 8`, Config4 is `row_groups = 1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VsMapping {
    /// VS vector width (the paper's K; base hardware width is 32, and the
    /// reconfiguration controller fuses base units into K in {32..256}).
    pub k: u64,
    /// Number of row-wise stacked groups of VS units.
    pub row_groups: u64,
}

impl VsMapping {
    pub fn new(k: u64, row_groups: u64) -> Self {
        VsMapping { k, row_groups }
    }
}

/// A SHARP accelerator configuration (Table 1).
#[derive(Debug, Clone, PartialEq)]
pub struct SharpConfig {
    /// Total multiply-adder units (1K / 4K / 16K / 64K in the paper).
    pub macs: u64,
    /// Clock frequency in Hz (500 MHz from the 1.94 ns fp16 multiplier).
    pub freq_hz: f64,
    /// VS-unit mapping for the MVM tile engine.
    pub mapping: VsMapping,
    /// Dynamic padding reconfiguration enabled (§6.2.1).
    pub padding_reconfig: bool,
    /// Weight SRAM capacity in bytes (26 MB).
    pub weight_buf_bytes: u64,
    /// Input/Hidden SRAM capacity in bytes (2.3 MB).
    pub ih_buf_bytes: u64,
    /// Cell-state scratchpad bytes (192 KB, double buffered).
    pub cell_buf_bytes: u64,
    /// Intermediate (unfolded input-MVM) buffer bytes (24 KB).
    pub inter_buf_bytes: u64,
    /// Number of activation MFUs (64).
    pub mfus: u64,
}

impl SharpConfig {
    /// The paper's default design at a given MAC budget: K = 32 base width,
    /// all VS units column-wise (Config4), reconfiguration on.
    pub fn with_macs(macs: u64) -> Self {
        SharpConfig {
            macs,
            freq_hz: 500e6,
            mapping: VsMapping::new(32, 1),
            padding_reconfig: true,
            weight_buf_bytes: 26 << 20,
            ih_buf_bytes: (23 << 20) / 10, // 2.3 MB
            cell_buf_bytes: 192 << 10,
            inter_buf_bytes: 24 << 10,
            mfus: 64,
        }
    }

    pub fn with_k(mut self, k: u64) -> Self {
        self.mapping.k = k;
        self
    }

    pub fn with_row_groups(mut self, g: u64) -> Self {
        self.mapping.row_groups = g;
        self
    }

    pub fn with_reconfig(mut self, on: bool) -> Self {
        self.padding_reconfig = on;
        self
    }

    pub fn with_freq(mut self, hz: f64) -> Self {
        self.freq_hz = hz;
        self
    }

    /// Number of VS units: N = MACs / K.
    pub fn n_vs(&self) -> u64 {
        ceil_div(self.macs, self.mapping.k)
    }

    /// Tile rows covered per cycle: row_groups * K (output dimension).
    pub fn tile_rows(&self) -> u64 {
        self.mapping.row_groups * self.mapping.k
    }

    /// Tile cols covered per cycle: N / row_groups (contraction dimension).
    pub fn tile_cols(&self) -> u64 {
        (self.n_vs() / self.mapping.row_groups).max(1)
    }

    /// Peak throughput in FLOP/s (2 flops per MAC per cycle).
    pub fn peak_flops(&self) -> f64 {
        2.0 * self.macs as f64 * self.freq_hz
    }

    /// Depth of the R-Add-Reduce tree that sums the column-wise VS results.
    pub fn reduce_levels(&self) -> u64 {
        let per_group = self.tile_cols().max(1);
        (64 - (per_group - 1).leading_zeros() as u64).max(1)
    }

    /// On-chip SRAM bytes streamed to the MACs per cycle (fp16 weights).
    pub fn weight_bytes_per_cycle(&self) -> u64 {
        self.macs * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults() {
        let c = SharpConfig::with_macs(4096);
        assert_eq!(c.macs, 4096);
        assert_eq!(c.freq_hz, 500e6);
        assert_eq!(c.mapping.k, 32);
        assert_eq!(c.weight_buf_bytes, 26 * 1024 * 1024);
        assert_eq!(c.mfus, 64);
    }

    #[test]
    fn peak_flops_match_table1() {
        // Table 1: 0.46 / 1.86 / 7.4 / 29.8 TFLOPS for 1K..64K @500MHz wait:
        // 2 * 1024 * 5e8 ~ 1.02 TFLOP? The paper counts MAC=1 flop... Using
        // 2 flops/MAC, 64K gives 65.5 TF; the paper's 29.8 TF for 64K implies
        // ~0.45 flops per MAC-cycle unit. We keep 2 flops/MAC (the standard
        // convention) and verify proportionality across budgets instead.
        let p1 = SharpConfig::with_macs(1024).peak_flops();
        let p64 = SharpConfig::with_macs(65536).peak_flops();
        assert!((p64 / p1 - 64.0).abs() < 1e-9);
    }

    #[test]
    fn vs_geometry() {
        let c = SharpConfig::with_macs(1024).with_k(32);
        assert_eq!(c.n_vs(), 32);
        assert_eq!(c.tile_rows(), 32);
        assert_eq!(c.tile_cols(), 32);
        let c8 = c.clone().with_row_groups(8);
        assert_eq!(c8.tile_rows(), 256);
        assert_eq!(c8.tile_cols(), 4);
        // Total lanes conserved across mappings.
        assert_eq!(c.tile_rows() * c.tile_cols(), c8.tile_rows() * c8.tile_cols());
    }

    #[test]
    fn reduce_levels_log2() {
        let c = SharpConfig::with_macs(1024).with_k(32); // 32 col-wise units
        assert_eq!(c.reduce_levels(), 5);
    }
}
