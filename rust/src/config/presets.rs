//! Named presets: the paper's resource budgets, benchmark networks
//! (Table 5), the DeepBench set (Table 4), and the Fig. 9 sweep dims.

use super::accel::SharpConfig;
use super::model::{CellKind, Direction, LstmConfig};

/// The paper's four resource budgets (Table 1).
pub const MAC_BUDGETS: [u64; 4] = [1024, 4096, 16384, 65536];

/// Human label for a MAC budget ("1K".."64K").
pub fn budget_label(macs: u64) -> String {
    if macs % 1024 == 0 {
        format!("{}K", macs / 1024)
    } else {
        format!("{macs}")
    }
}

/// K-widths explored in Fig. 9.
pub const K_SWEEP: [u64; 5] = [32, 64, 128, 256, 512];

/// K-widths the reconfigurable hardware can realize by fusing base-32 VS
/// units (§6.2.2: "select between the four options from 32 to 256").
pub const K_RECONFIG: [u64; 4] = [32, 64, 128, 256];

/// LSTM hidden dimensions swept in Figs. 9-14, "selected from the LSTM
/// networks of popular applications" (§7): ragged dims like EESEN's 340
/// and the LM's 1500 alongside the clean 512/1024 — Fig. 10 singles out
/// 512 as the only dimension with no MVM padding, so the sweep must mix
/// ragged and aligned sizes.
pub const HIDDEN_SWEEP: [u64; 6] = [180, 340, 512, 750, 1024, 1500];

/// All four budget presets.
pub fn all_budgets() -> Vec<SharpConfig> {
    MAC_BUDGETS.iter().map(|&m| SharpConfig::with_macs(m)).collect()
}

/// Table 5: EESEN speech recognition — 5 bidirectional layers, 340 units.
pub fn eesen() -> LstmConfig {
    LstmConfig {
        name: "EESEN".into(),
        layers: 5,
        hidden: 340,
        input: 340,
        seq_len: 500, // paper: 300-700; midpoint
        direction: Direction::Bidirectional,
        batch: 1,
        cell: CellKind::Lstm,
    }
}

/// Table 5: GMAT (GNMT-like machine translation) — 17 layers, 1024 units.
pub fn gmat() -> LstmConfig {
    LstmConfig {
        name: "GMAT".into(),
        layers: 17,
        hidden: 1024,
        input: 1024,
        seq_len: 75, // paper: 50-100
        direction: Direction::Unidirectional,
        batch: 1,
        cell: CellKind::Lstm,
    }
}

/// Table 5: BYSDNE video classification — 5 layers, 340 units, T = 30.
pub fn bysdne() -> LstmConfig {
    LstmConfig {
        name: "BYSDNE".into(),
        layers: 5,
        hidden: 340,
        input: 340,
        seq_len: 30,
        direction: Direction::Unidirectional,
        batch: 1,
        cell: CellKind::Lstm,
    }
}

/// Table 5: RLDRADSPR (Residual LSTM distant speech) — 10 stacked, 1024.
pub fn rldradspr() -> LstmConfig {
    LstmConfig {
        name: "RLDRADSPR".into(),
        layers: 10,
        hidden: 1024,
        input: 1024,
        seq_len: 400, // paper: 300-512
        direction: Direction::Unidirectional,
        batch: 1,
        cell: CellKind::Lstm,
    }
}

/// The four real-world networks of Tables 5/6.
pub fn table5_networks() -> Vec<LstmConfig> {
    vec![eesen(), gmat(), bysdne(), rldradspr()]
}

/// Table 4: Baidu DeepBench LSTM inference configurations.
pub fn deepbench() -> Vec<LstmConfig> {
    vec![
        LstmConfig::square(256).with_seq_len(150).named("db_h256_t150"),
        LstmConfig::square(512).with_seq_len(25).named("db_h512_t25"),
        LstmConfig::square(1024).with_seq_len(25).named("db_h1024_t25"),
        LstmConfig::square(1536).with_seq_len(50).named("db_h1536_t50"),
    ]
}

/// Fig. 1 applications (hidden dims of the cited networks).
pub fn fig1_apps() -> Vec<LstmConfig> {
    vec![
        // Machine comprehension: BiDAF-style, small hidden dim.
        LstmConfig {
            name: "MC".into(),
            layers: 3,
            hidden: 100,
            input: 100,
            seq_len: 60,
            direction: Direction::Bidirectional,
            batch: 1,
            cell: CellKind::Lstm,
        },
        // Speech recognition: EESEN-style.
        LstmConfig {
            name: "SR".into(),
            layers: 5,
            hidden: 340,
            input: 340,
            seq_len: 500,
            direction: Direction::Bidirectional,
            batch: 1,
            cell: CellKind::Lstm,
        },
        // Language modeling: large regularized LSTM.
        LstmConfig {
            name: "LM".into(),
            layers: 2,
            hidden: 1500,
            input: 1500,
            seq_len: 35,
            direction: Direction::Unidirectional,
            batch: 1,
            cell: CellKind::Lstm,
        },
        // Machine translation: GNMT-style.
        LstmConfig {
            name: "MT".into(),
            layers: 8,
            hidden: 1024,
            input: 1024,
            seq_len: 60,
            direction: Direction::Unidirectional,
            batch: 1,
            cell: CellKind::Lstm,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_labels() {
        assert_eq!(budget_label(1024), "1K");
        assert_eq!(budget_label(65536), "64K");
        assert_eq!(budget_label(96 * 1024), "96K");
    }

    #[test]
    fn table5_shapes() {
        let nets = table5_networks();
        assert_eq!(nets.len(), 4);
        assert_eq!(nets[0].name, "EESEN");
        assert_eq!(nets[0].dirs(), 2);
        assert_eq!(nets[1].hidden, 1024);
        assert_eq!(nets[3].layers, 10);
    }

    #[test]
    fn deepbench_matches_table4() {
        let db = deepbench();
        assert_eq!(db[0].hidden, 256);
        assert_eq!(db[0].seq_len, 150);
        assert_eq!(db[3].hidden, 1536);
        assert_eq!(db[3].seq_len, 50);
    }

    #[test]
    fn all_budgets_are_table1() {
        let b = all_budgets();
        assert_eq!(b.len(), 4);
        assert_eq!(b[3].macs, 65536);
    }
}
