//! LSTM model geometry — the only model property the accelerator's timing
//! depends on (weights values never affect cycle counts).

/// Direction of an LSTM network (paper Table 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Unidirectional,
    Bidirectional,
}

/// Recurrent cell family. Paper §8: "the same improvement can be achieved
/// in other networks that have similar design, such as GRU" — the GRU has
/// 3 gates instead of 4 and no separate cell state, which changes only
/// the fused gate-matrix height and the update-stage drain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellKind {
    Lstm,
    Gru,
}

impl CellKind {
    /// Gates per cell: rows of the fused gate matrix are `gates() * H`.
    pub fn gates(&self) -> u64 {
        match self {
            CellKind::Lstm => 4,
            CellKind::Gru => 3,
        }
    }

    /// Activation ops per hidden element per step (LSTM: 4 gate act +
    /// tanh(c); GRU: 2 sigmoid + 1 tanh).
    pub fn act_ops_per_elem(&self) -> u64 {
        match self {
            CellKind::Lstm => 5,
            CellKind::Gru => 3,
        }
    }
}

/// Geometry of one LSTM workload.
#[derive(Debug, Clone, PartialEq)]
pub struct LstmConfig {
    /// Human-readable name (benchmark identity in tables).
    pub name: String,
    /// Number of stacked layers.
    pub layers: u64,
    /// Hidden units per direction.
    pub hidden: u64,
    /// Input feature dimension of the first layer (Fig. 9 assumes == hidden).
    pub input: u64,
    /// Sequence length (time steps).
    pub seq_len: u64,
    /// Uni- or bi-directional cells.
    pub direction: Direction,
    /// Inference batch size (SLA-constrained; 1 for online serving).
    pub batch: u64,
    /// Cell family (LSTM by default; GRU for the §8 generality claim).
    pub cell: CellKind,
}

impl LstmConfig {
    /// Square model used across Fig. 9 / 11 / 12 sweeps: input == hidden,
    /// unidirectional, a single layer, T = 25, batch 1.
    pub fn square(hidden: u64) -> Self {
        LstmConfig {
            name: format!("h{hidden}"),
            layers: 1,
            hidden,
            input: hidden,
            seq_len: 25,
            direction: Direction::Unidirectional,
            batch: 1,
            cell: CellKind::Lstm,
        }
    }

    pub fn with_cell(mut self, cell: CellKind) -> Self {
        self.cell = cell;
        self
    }

    /// Gates of the configured cell family.
    pub fn gates(&self) -> u64 {
        self.cell.gates()
    }

    pub fn with_seq_len(mut self, t: u64) -> Self {
        self.seq_len = t;
        self
    }

    pub fn with_layers(mut self, l: u64) -> Self {
        self.layers = l;
        self
    }

    pub fn with_batch(mut self, b: u64) -> Self {
        self.batch = b;
        self
    }

    pub fn named(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    /// Directions factor (2 for bidirectional).
    pub fn dirs(&self) -> u64 {
        match self.direction {
            Direction::Unidirectional => 1,
            Direction::Bidirectional => 2,
        }
    }

    /// Input dimension seen by layer `l` (0-based): first layer sees
    /// `input`, deeper layers consume the concatenated directional outputs.
    pub fn layer_input_dim(&self, l: u64) -> u64 {
        if l == 0 {
            self.input
        } else {
            self.hidden * self.dirs()
        }
    }

    /// MAC operations for the whole network, one inference of one batch
    /// element (each MAC = 1 multiply + 1 add).
    pub fn total_macs(&self) -> u64 {
        let g = self.gates();
        let mut total = 0;
        for l in 0..self.layers {
            let d = self.layer_input_dim(l);
            // Per time step per direction: fused gate matrix (gH x (D+H)).
            total += self.dirs() * self.seq_len * g * self.hidden * (d + self.hidden);
        }
        total * self.batch
    }

    /// FLOPs per inference (2 per MAC, ignoring the pointwise tail like the
    /// paper's utilization math does).
    pub fn total_flops(&self) -> f64 {
        2.0 * self.total_macs() as f64
    }

    /// fp16 bytes of all weight matrices (for buffer-fit and DRAM fill).
    pub fn weight_bytes(&self) -> u64 {
        let g = self.gates();
        let mut params = 0;
        for l in 0..self.layers {
            let d = self.layer_input_dim(l);
            params += self.dirs() * (g * self.hidden * (d + self.hidden) + g * self.hidden);
        }
        params * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_geometry() {
        let m = LstmConfig::square(512);
        assert_eq!(m.hidden, 512);
        assert_eq!(m.input, 512);
        assert_eq!(m.seq_len, 25);
        assert_eq!(m.dirs(), 1);
        // 25 steps * 4H(D+H) = 25 * 4*512*1024
        assert_eq!(m.total_macs(), 25 * 4 * 512 * 1024);
    }

    #[test]
    fn bidirectional_doubles_work() {
        let mut m = LstmConfig::square(256);
        let uni = m.total_macs();
        m.direction = Direction::Bidirectional;
        assert_eq!(m.total_macs(), 2 * uni);
    }

    #[test]
    fn stacked_layer_dims() {
        let mut m = LstmConfig::square(128).with_layers(3);
        assert_eq!(m.layer_input_dim(0), 128);
        assert_eq!(m.layer_input_dim(1), 128);
        m.direction = Direction::Bidirectional;
        assert_eq!(m.layer_input_dim(1), 256); // concat of both directions
    }

    #[test]
    fn weight_bytes_fp16() {
        let m = LstmConfig::square(64).with_layers(1);
        // (4*64*128 weights + 4*64 bias) * 2 bytes
        assert_eq!(m.weight_bytes(), (4 * 64 * 128 + 256) * 2);
    }
}

#[cfg(test)]
mod gru_tests {
    use super::*;

    #[test]
    fn gru_has_three_gates() {
        assert_eq!(CellKind::Gru.gates(), 3);
        assert_eq!(CellKind::Lstm.gates(), 4);
    }

    #[test]
    fn gru_work_is_three_quarters_of_lstm() {
        let lstm = LstmConfig::square(256);
        let gru = LstmConfig::square(256).with_cell(CellKind::Gru);
        assert_eq!(4 * gru.total_macs(), 3 * lstm.total_macs());
        assert!(gru.weight_bytes() < lstm.weight_bytes());
    }

    #[test]
    fn act_ops_reflect_cell_family() {
        assert_eq!(CellKind::Lstm.act_ops_per_elem(), 5);
        assert_eq!(CellKind::Gru.act_ops_per_elem(), 3);
    }
}
