//! Small self-contained utilities (no external deps beyond std).
//!
//! The offline crate registry has neither `rand`, `serde`, nor `proptest`,
//! so this module carries the minimal replacements the rest of the crate
//! needs: a deterministic PRNG, streaming stats, a JSON reader/writer for
//! the artifact manifest, and aligned text tables for the figure output.

pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `m`.
#[inline]
pub fn round_up(a: u64, m: u64) -> u64 {
    ceil_div(a, m) * m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(8, 4), 2);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 32), 0);
        assert_eq!(round_up(1, 32), 32);
        assert_eq!(round_up(32, 32), 32);
        assert_eq!(round_up(33, 32), 64);
    }
}
