//! Aligned text tables — every figure/table generator renders through this,
//! so the benches and the CLI print the same rows the paper reports.

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table {
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn header<S: ToString>(mut self, cols: &[S]) -> Self {
        self.header = cols.iter().map(|c| c.to_string()).collect();
        self
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) -> &mut Self {
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Table title (empty when untitled).
    pub fn title(&self) -> &str {
        &self.title
    }

    /// Header cells (empty when headerless).
    pub fn header_cells(&self) -> &[String] {
        &self.header
    }

    /// All data rows (the machine-readable view the JSON emitter walks).
    pub fn data_rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        let all_rows = std::iter::once(&self.header).chain(self.rows.iter());
        for row in all_rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |row: &[String]| -> String {
            let mut line = String::new();
            for (i, cell) in row.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = widths[i].saturating_sub(cell.chars().count());
                // Right-align numeric-looking cells, left-align text.
                let numeric = cell
                    .chars()
                    .all(|c| c.is_ascii_digit() || ".-+%xX".contains(c))
                    && !cell.is_empty();
                if numeric {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                } else {
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                }
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a f64 with engineering-friendly precision for table cells.
pub fn fnum(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

/// Format a ratio as "2.35x".
pub fn fx(v: f64) -> String {
    format!("{}x", fnum(v))
}

/// Format a fraction as a percentage.
pub fn fpct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["name", "value"]);
        t.row(&["alpha", "1.0"]);
        t.row(&["b", "22.5"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("alpha"));
        // Numeric column right-aligned: "22.5" ends both lines at same col.
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn fnum_scales() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.0), "1234");
        assert_eq!(fnum(12.34), "12.3");
        assert_eq!(fnum(1.234), "1.23");
    }

    #[test]
    fn fpct_and_fx() {
        assert_eq!(fpct(0.5), "50.0%");
        assert_eq!(fx(2.0), "2.00x");
    }
}
