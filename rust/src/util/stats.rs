//! Streaming statistics: mean, percentiles, histograms.
//!
//! Used by the coordinator's latency metrics and by the benchmark harness
//! (the offline registry has no `criterion`, so `benches/` carries its own
//! timing loop and reports through these helpers).

/// A collected sample set with percentile queries.
#[derive(Debug, Default, Clone)]
pub struct Samples {
    values: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// Append every sample of `other` (per-worker metrics merge).
    pub fn extend_from(&mut self, other: &Samples) {
        self.values.extend_from_slice(&other.values);
        self.sorted = false;
    }

    /// Overwrite the sample at `idx` (ring-buffer reuse by bounded
    /// collectors — percentiles are order-free, so position is
    /// meaningless and reuse is safe).
    pub fn replace(&mut self, idx: usize, v: f64) {
        self.values[idx] = v;
        self.sorted = false;
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    /// Nearest-rank percentile, `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.values
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
            self.sorted = true;
        }
        // Nearest-rank: ceil(p/100 * n) - 1, clamped.
        let n = self.values.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        self.values[rank.clamp(1, n) - 1]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Geometric mean of a slice (used for "average speedup" rows, matching how
/// accelerator papers aggregate ratios).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentiles() {
        let mut s = Samples::new();
        for v in 1..=100 {
            s.push(v as f64);
        }
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.p99(), 99.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(0.0), 1.0);
    }

    #[test]
    fn empty_samples_are_safe() {
        let mut s = Samples::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p95(), 0.0);
    }

    #[test]
    fn extend_from_merges_sample_sets() {
        let mut a = Samples::new();
        a.push(1.0);
        a.push(3.0);
        let mut b = Samples::new();
        b.push(2.0);
        let _ = a.percentile(50.0); // force the sorted state...
        a.extend_from(&b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.p50(), 2.0); // ...which the merge must invalidate
        assert_eq!(a.max(), 3.0);
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
        assert!((geomean(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_constant_is_zero() {
        let mut s = Samples::new();
        for _ in 0..10 {
            s.push(5.0);
        }
        assert_eq!(s.stddev(), 0.0);
    }
}
