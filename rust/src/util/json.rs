//! Minimal JSON parser + writer (the offline registry has no `serde`).
//!
//! Scope: exactly what the artifact manifest (`artifacts/manifest.json`)
//! and the coordinator's metrics dump need — objects, arrays, strings,
//! f64 numbers, bools, null. Not a general-purpose validator: it accepts
//! valid JSON and rejects what it cannot understand with an offset error.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError {
            offset: self.pos,
            message: msg.to_string(),
        })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| ParseError {
                offset: start,
                message: "invalid utf8 in number".into(),
            })?;
        text.parse::<f64>().map(Json::Num).map_err(|_| ParseError {
            offset: start,
            message: format!("bad number '{text}'"),
        })
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| ParseError {
                                        offset: self.pos,
                                        message: "bad \\u escape".into(),
                                    })?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| ParseError {
                                offset: self.pos,
                                message: "bad \\u escape".into(),
                            })?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let s = std::str::from_utf8(&self.bytes[self.pos..]).map_err(|_| {
                        ParseError {
                            offset: self.pos,
                            message: "invalid utf8".into(),
                        }
                    })?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

/// Serialize a JSON value (compact).
pub fn write(value: &Json) -> String {
    let mut out = String::new();
    write_into(value, &mut out);
    out
}

fn write_into(value: &Json, out: &mut String) {
    match value {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, v)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Json::Str(k.clone()), out);
                out.push(':');
                write_into(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{"version": 1, "artifacts": [{"name": "cell_h64_b1", "T": 1,
            "inputs": [{"name": "x", "shape": [1, 64], "file": "a.f32"}]}]}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_u64(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("cell_h64_b1"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 2);
        assert_eq!(shape[1].as_u64(), Some(64));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3],"b":"x\ny","c":true,"d":null}"#;
        let v = parse(doc).unwrap();
        let text = write(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""aAb""#).unwrap();
        assert_eq!(v.as_str(), Some("aAb"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-2.5").unwrap().as_f64(), Some(-2.5));
    }
}
