//! Deterministic xorshift64* PRNG.
//!
//! Used by the workload trace generator, the serving examples, and the
//! hand-rolled property tests (the offline registry has no `rand`/`proptest`).
//! xorshift64* passes BigCrush for our purposes (non-crypto simulation
//! randomness) and is trivially reproducible across runs.

/// xorshift64* generator; deterministic for a given seed.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator. A zero seed is remapped (xorshift fixpoint).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high-quality mantissa bits.
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Uniform usize in [lo, hi] (inclusive).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range_usize(0, items.len() - 1)]
    }

    /// Exponentially distributed inter-arrival gap with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = self.next_f64().max(1e-12);
        -mean * u.ln()
    }

    /// Vector of uniform f32s (for synthetic request payloads).
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform_f32(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.range_u64(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn exp_mean_approximately_right() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }
}
