//! Power/energy report — turns the simulator's activity factors into the
//! Fig. 15 power breakdown and the Fig. 14 energy comparison.

use crate::config::SharpConfig;
use crate::sim::SimResult;

use super::cacti::{weight_banks_for, Sram};
use super::dram;
use super::synthesis as syn;

/// Power breakdown of one simulated run, watts per component.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerReport {
    pub compute_w: f64,
    pub sram_w: f64,
    pub dram_w: f64,
    pub activation_w: f64,
    pub controller_w: f64,
    /// Wall-clock of the run the powers are averaged over.
    pub time_s: f64,
}

impl PowerReport {
    pub fn total_w(&self) -> f64 {
        self.compute_w + self.sram_w + self.dram_w + self.activation_w + self.controller_w
    }

    /// Total energy of the run, joules.
    pub fn energy_j(&self) -> f64 {
        self.total_w() * self.time_s
    }

    /// Component shares (compute, sram, dram, activation, controller).
    pub fn shares(&self) -> [f64; 5] {
        let t = self.total_w();
        [
            self.compute_w / t,
            self.sram_w / t,
            self.dram_w / t,
            self.activation_w / t,
            self.controller_w / t,
        ]
    }

    /// Energy efficiency in FLOPS/W for a given achieved FLOP/s.
    pub fn flops_per_watt(&self, achieved_flops: f64) -> f64 {
        achieved_flops / self.total_w()
    }
}

/// Build the power report for a simulated run.
///
/// Dynamic energy = activity x per-op energy (padded lanes clock the
/// multipliers too, which is how padding costs energy, not just time);
/// static energy = leakage x time.
pub fn power_report(cfg: &SharpConfig, sim: &SimResult) -> PowerReport {
    let t = sim.time_s().max(1e-12);

    // Compute unit: all issued lanes (useful + padded) burn MAC energy.
    let mac_ops = sim.useful_lane_cycles + sim.padded_lane_cycles;
    let compute_dyn = mac_ops as f64 * syn::E_MAC_J;
    let compute_leak = cfg.macs as f64 * syn::P_MAC_LEAK_W;
    let compute_w = compute_dyn / t + compute_leak;

    // SRAM buffers: weight stream + I/H + scratch traffic, plus leakage.
    let banks = weight_banks_for(cfg.macs);
    let wbuf = Sram::new(cfg.weight_buf_bytes, banks);
    let ihbuf = Sram::new(cfg.ih_buf_bytes, (banks / 4).max(2));
    let scratch = Sram::new(cfg.cell_buf_bytes + cfg.inter_buf_bytes, 4);
    let sram_dyn = sim.traffic.weight_sram_bytes as f64 * wbuf.energy_per_byte()
        + sim.traffic.ih_sram_bytes as f64 * ihbuf.energy_per_byte()
        + sim.traffic.scratch_bytes as f64 * scratch.energy_per_byte();
    let sram_leak = wbuf.leakage_w() + ihbuf.leakage_w() + scratch.leakage_w();
    let sram_w = sram_dyn / t + sram_leak;

    let dram_w = dram::avg_power_w(
        sim.traffic.dram_bytes,
        t,
        crate::sim::memory::dram_bw_bytes_per_s(cfg.macs),
    );

    let act_dyn = sim.act_ops as f64 * syn::E_ACT_J + sim.cu_ops as f64 * syn::E_CU_J;
    let activation_w = act_dyn / t + syn::P_ACT_LEAK_W;

    PowerReport {
        compute_w,
        sram_w,
        dram_w,
        activation_w,
        controller_w: syn::P_CTRL_W,
        time_s: t,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LstmConfig, SharpConfig};
    use crate::sched::ScheduleKind;
    use crate::sim::simulate;

    fn report(macs: u64, h: u64) -> PowerReport {
        let cfg = SharpConfig::with_macs(macs);
        let model = LstmConfig::square(h);
        let sim = simulate(&cfg, &model, ScheduleKind::Unfolded);
        power_report(&cfg, &sim)
    }

    #[test]
    fn totals_in_fig15_band() {
        // Fig. 15: 8.11 / 11.36 / 22.13 / 47.7 W for 1K..64K (averaged
        // over apps). Our single-model average should land within ~35%.
        let paper = [(1024u64, 8.11), (4096, 11.36), (16384, 22.13), (65536, 47.7)];
        for (macs, watts) in paper {
            let p = report(macs, 512).total_w();
            let err = (p - watts).abs() / watts;
            assert!(err < 0.35, "macs={macs}: {p:.1} W vs paper {watts} W");
        }
    }

    #[test]
    fn sram_dominates_small_designs() {
        let p = report(1024, 512);
        assert!(p.sram_w > p.compute_w, "Fig. 15: SRAM dominant at 1K");
    }

    #[test]
    fn compute_dominates_large_designs() {
        let p = report(65536, 512);
        assert!(p.compute_w > p.sram_w, "Fig. 15: compute dominant at 64K");
    }

    #[test]
    fn controller_below_one_percent() {
        for macs in [1024u64, 65536] {
            let p = report(macs, 512);
            assert!(p.controller_w / p.total_w() < 0.01);
        }
    }

    #[test]
    fn energy_is_power_times_time() {
        let p = report(4096, 256);
        assert!((p.energy_j() - p.total_w() * p.time_s).abs() < 1e-12);
    }

    #[test]
    fn shares_sum_to_one() {
        let p = report(16384, 1024);
        assert!((p.shares().iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    /// Golden values: a hand-built SimResult over exactly one second at the
    /// 4K-MAC design point, with activity counts chosen so every component
    /// reduces to literal arithmetic on the synthesis/DRAM constants. Any
    /// constant or wiring change in power_report moves one of these.
    #[test]
    fn golden_component_watts_for_a_synthetic_run() {
        use crate::sim::memory::MemTraffic;
        let cfg = SharpConfig::with_macs(4096);
        let sim = SimResult {
            // 500M cycles at 500 MHz -> exactly 1.0 s of wall clock.
            cycles: 500_000_000,
            mac_issue_cycles: 500_000_000,
            useful_lane_cycles: 1_000_000_000_000,
            padded_lane_cycles: 250_000_000_000,
            exposed_tail_cycles: 0,
            act_ops: 500_000_000_000,
            cu_ops: 1_000_000_000_000,
            traffic: MemTraffic {
                weight_sram_bytes: 0,
                ih_sram_bytes: 0,
                scratch_bytes: 0,
                // Exactly the 4K design's 44 GB/s for one second.
                dram_bytes: 44_000_000_000,
            },
            freq_hz: 500e6,
            macs: 4096,
        };
        let p = power_report(&cfg, &sim);
        assert!((p.time_s - 1.0).abs() < 1e-15);

        // Compute: 1.25e12 lane-cycles * 0.8 pJ = 1.0 W dynamic, plus
        // 4096 lanes * 0.8e-4 W leakage = 0.32768 W.
        assert!((p.compute_w - 1.32768).abs() < 1e-9, "compute {}", p.compute_w);

        // SRAM: zero traffic -> pure leakage of the three buffers, which
        // the cacti golden test pins per-macro.
        let banks = weight_banks_for(cfg.macs);
        let leak = Sram::new(cfg.weight_buf_bytes, banks).leakage_w()
            + Sram::new(cfg.ih_buf_bytes, (banks / 4).max(2)).leakage_w()
            + Sram::new(cfg.cell_buf_bytes + cfg.inter_buf_bytes, 4).leakage_w();
        assert!((p.sram_w - leak).abs() < 1e-12, "sram {}", p.sram_w);

        // DRAM: 0.12 W static + 44e9 B/s * 14 pJ/B = 0.736 W.
        assert!((p.dram_w - 0.736).abs() < 1e-9, "dram {}", p.dram_w);

        // Activation: 5e11 * 6 pJ + 1e12 * 1 pJ = 4.0 W dynamic + 0.35 W
        // leakage = 4.35 W; controller is the flat 0.05 W.
        assert!((p.activation_w - 4.35).abs() < 1e-9, "act {}", p.activation_w);
        assert!((p.controller_w - 0.05).abs() < 1e-15);

        let total = 1.32768 + leak + 0.736 + 4.35 + 0.05;
        assert!((p.total_w() - total).abs() < 1e-9);
        assert!((p.energy_j() - total).abs() < 1e-9, "1 s -> W == J");
    }

    /// Golden values for the report arithmetic itself, detached from the
    /// simulator: totals, energy, shares, and FLOPS/W on round numbers.
    #[test]
    fn golden_report_arithmetic() {
        let p = PowerReport {
            compute_w: 1.0,
            sram_w: 2.0,
            dram_w: 3.0,
            activation_w: 4.0,
            controller_w: 0.5,
            time_s: 2.0,
        };
        assert_eq!(p.total_w(), 10.5);
        assert_eq!(p.energy_j(), 21.0);
        assert_eq!(p.flops_per_watt(21.0), 2.0);
        let shares = p.shares();
        assert!((shares[0] - 1.0 / 10.5).abs() < 1e-15);
        assert!((shares[4] - 0.5 / 10.5).abs() < 1e-15);
    }
}
