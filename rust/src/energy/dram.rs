//! Off-chip DRAM power model (paper §7: Micron power model, 8-GB LPDDR).
//!
//! DRAM shows up in the initial per-layer weight fill and the input
//! stream; its power share grows with the MAC budget because higher
//! budgets demand more fill bandwidth (Fig. 15: "main memory consumes
//! more power and energy as the number of MACs grows").

/// Dynamic access energy per byte moved over the LPDDR interface.
/// Micron-model class number for LPDDR at this generation: ~12 pJ/b
/// including I/O -> ~15 pJ/B at the modeled burst efficiency... using
/// 14 pJ/B as the anchor that reproduces Fig. 15's main-memory share
/// growth from ~2% (1K) to ~15% (64K).
pub const E_DRAM_PER_BYTE_J: f64 = 14.0e-12;

/// Background/static power of the 8-GB device (self-refresh + standby).
pub const P_DRAM_STATIC_W: f64 = 0.12;

/// Energy of a DRAM transfer of `bytes`.
pub fn transfer_energy_j(bytes: u64) -> f64 {
    bytes as f64 * E_DRAM_PER_BYTE_J
}

/// Average DRAM power for `bytes` moved over `seconds`, capped by what
/// the interface at `bw_bytes_per_s` can physically stream (the weight
/// preload is bandwidth-bound, not instantaneous — without the cap a
/// short compute window would ascribe the whole preload energy to it).
pub fn avg_power_w(bytes: u64, seconds: f64, bw_bytes_per_s: f64) -> f64 {
    if seconds <= 0.0 {
        return P_DRAM_STATIC_W;
    }
    let streamed = (bytes as f64 / seconds).min(bw_bytes_per_s);
    P_DRAM_STATIC_W + streamed * E_DRAM_PER_BYTE_J
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_linear_in_bytes() {
        assert!((transfer_energy_j(2_000) - 2.0 * transfer_energy_j(1_000)).abs() < 1e-18);
    }

    #[test]
    fn power_includes_static_floor() {
        assert!(avg_power_w(0, 1.0, 561e9) >= P_DRAM_STATIC_W);
        assert!(avg_power_w(1 << 30, 1.0, 561e9) > avg_power_w(1 << 20, 1.0, 561e9));
    }

    #[test]
    fn degenerate_time_is_safe() {
        assert_eq!(avg_power_w(123, 0.0, 561e9), P_DRAM_STATIC_W);
    }

    #[test]
    fn bandwidth_caps_power() {
        // A burst far beyond the bus cannot draw unbounded power.
        let capped = avg_power_w(u64::MAX / 2, 1e-9, 561e9);
        assert!(capped <= P_DRAM_STATIC_W + 561e9 * E_DRAM_PER_BYTE_J + 1e-9);
    }
}
