//! CACTI-P-like SRAM model (paper §7 modeled buffers in CACTI-P at 32 nm).
//!
//! Analytic stand-in for the CACTI tool: per-access energy and leakage as
//! functions of capacity and banking, with the constants anchored so the
//! aggregate SRAM area/power reproduce the paper's Table 2 and Fig. 15
//! splits. Only relative splits matter to the paper's claims.

/// An SRAM macro (one of SHARP's buffers).
#[derive(Debug, Clone, Copy)]
pub struct Sram {
    pub bytes: u64,
    pub banks: u64,
}

impl Sram {
    pub fn new(bytes: u64, banks: u64) -> Self {
        Sram {
            bytes,
            banks: banks.max(1),
        }
    }

    fn capacity_mb(&self) -> f64 {
        self.bytes as f64 / (1024.0 * 1024.0)
    }

    /// Dynamic read/write energy per byte, joules. CACTI-like: grows with
    /// the square root of per-bank capacity (bitline/wordline length),
    /// with a floor for periphery. The weight buffer's heavy banking (one
    /// bank per VS-unit group) keeps per-access energy near the floor —
    /// that is what makes the paper's TB/s-scale on-chip streaming viable.
    pub fn energy_per_byte(&self) -> f64 {
        let per_bank_mb = self.capacity_mb() / self.banks as f64;
        0.05e-12 + 0.1e-12 * per_bank_mb.max(1e-4).sqrt()
    }

    /// Leakage power, watts: proportional to capacity with a small
    /// per-bank periphery adder (banking costs leakage — this is why the
    /// 64K design's SRAM power grows in Fig. 15 despite equal capacity).
    pub fn leakage_w(&self) -> f64 {
        0.22 * self.capacity_mb() + 6.0e-3 * self.banks as f64
    }

    /// Silicon area, mm^2: linear in capacity plus banking overhead.
    /// Anchors (Table 2 SRAM rows): 28.7 MB total across buffers ->
    /// 87.1 mm^2 at 1K MACs (few banks) rising to 104.2 mm^2 at 64K
    /// (64x banks): base ~2.9 mm^2/MB, ~0.28 mm^2 per doubling of banks
    /// per MB-scale macro.
    pub fn area_mm2(&self) -> f64 {
        let base = 2.65 * self.capacity_mb();
        let bank_overhead = 0.55 * (self.banks as f64).log2().max(0.0) * self.capacity_mb().sqrt();
        base + bank_overhead
    }
}

/// The number of weight-buffer banks needed to feed `macs` lanes per cycle
/// (paper: "we increase the banks of SRAM buffers proportional to the VS
/// units").
pub fn weight_banks_for(macs: u64) -> u64 {
    (macs / 1024).max(1) * 16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_grows_with_bank_size() {
        let small = Sram::new(1 << 20, 16);
        let big = Sram::new(32 << 20, 16);
        assert!(big.energy_per_byte() > small.energy_per_byte());
    }

    #[test]
    fn banking_cuts_access_energy_but_adds_leakage() {
        let few = Sram::new(26 << 20, 16);
        let many = Sram::new(26 << 20, 1024);
        assert!(many.energy_per_byte() < few.energy_per_byte());
        assert!(many.leakage_w() > few.leakage_w());
    }

    #[test]
    fn area_anchored_to_table2_range() {
        // All SHARP buffers (26 + 2.3 + 0.19 + 0.02 MB) at 1K-MAC banking
        // should land near the paper's 87 mm^2; 64K banking near 104 mm^2.
        let mb = |m: f64| (m * 1024.0 * 1024.0) as u64;
        let total = |banks: u64| {
            Sram::new(mb(26.0), banks).area_mm2()
                + Sram::new(mb(2.3), banks / 4 + 1).area_mm2()
                + Sram::new(mb(0.1875), 2).area_mm2()
                + Sram::new(mb(0.0234), 2).area_mm2()
        };
        let a1 = total(weight_banks_for(1024));
        let a64 = total(weight_banks_for(65536));
        assert!((80.0..95.0).contains(&a1), "1K SRAM area {a1:.1}");
        assert!(a64 > a1, "banking must add area");
        assert!((95.0..115.0).contains(&a64), "64K SRAM area {a64:.1}");
    }

    #[test]
    fn banks_scale_with_macs() {
        assert_eq!(weight_banks_for(1024) * 64, weight_banks_for(65536));
    }

    /// Golden values: pin the model's constants exactly so an accidental
    /// edit to any coefficient (floor, sqrt slope, leakage, area anchors)
    /// shows up as a failing literal, not as a drifted Fig. 15 band.
    #[test]
    fn golden_values_for_a_1mb_16_bank_macro() {
        let s = Sram::new(1 << 20, 16);
        // per-bank 1/16 MB -> sqrt = 0.25 -> 0.05e-12 + 0.1e-12 * 0.25.
        let epb = s.energy_per_byte();
        assert!((epb - 0.075e-12).abs() < 1e-27, "epb {epb:e}");
        // 0.22 * 1 MB + 6e-3 * 16 banks.
        let leak = s.leakage_w();
        assert!((leak - 0.316).abs() < 1e-12, "leakage {leak}");
        // 2.65 * 1 MB + 0.55 * log2(16) * sqrt(1 MB).
        let area = s.area_mm2();
        assert!((area - 4.85).abs() < 1e-12, "area {area}");
    }

    #[test]
    fn golden_weight_bank_counts() {
        assert_eq!(weight_banks_for(1024), 16);
        assert_eq!(weight_banks_for(4096), 64);
        assert_eq!(weight_banks_for(65536), 1024);
        // Sub-1K designs floor at one 16-bank group.
        assert_eq!(weight_banks_for(1), 16);
    }
}
