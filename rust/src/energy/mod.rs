//! Energy, power, and area models (paper §7: Synopsys DC + CACTI-P +
//! Micron LPDDR, 32 nm, 0.85 V, typical-typical corner).
//!
//! We cannot run the proprietary tool flow, so `synthesis` encodes the
//! *published* synthesis-derived constants (with provenance comments) and
//! `cacti`/`dram` provide analytic models anchored to the paper's own
//! reported breakdowns (Table 2 area split, Fig. 15 power split). The
//! simulator supplies activity factors; this module turns them into
//! dynamic + static energy, power, and silicon area.

pub mod area;
pub mod cacti;
pub mod dram;
pub mod power;
pub mod synthesis;

pub use area::{area_breakdown, AreaBreakdown};
pub use power::{power_report, PowerReport};
