//! Area model — regenerates paper Table 2 (area breakdown by component
//! and total mm^2 for the four budgets).

use crate::config::SharpConfig;

use super::cacti::{weight_banks_for, Sram};
use super::synthesis;

/// Area breakdown of one SHARP configuration, mm^2 per component.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaBreakdown {
    pub compute_mm2: f64,
    pub sram_mm2: f64,
    pub mfu_mm2: f64,
    pub interconnect_mm2: f64,
    pub controller_mm2: f64,
}

impl AreaBreakdown {
    pub fn total_mm2(&self) -> f64 {
        self.compute_mm2
            + self.sram_mm2
            + self.mfu_mm2
            + self.interconnect_mm2
            + self.controller_mm2
    }

    /// Component shares in Table 2's order (compute, SRAM, MFU,
    /// interconnect/add-reduce, controller), as fractions of total.
    pub fn shares(&self) -> [f64; 5] {
        let t = self.total_mm2();
        [
            self.compute_mm2 / t,
            self.sram_mm2 / t,
            self.mfu_mm2 / t,
            self.interconnect_mm2 / t,
            self.controller_mm2 / t,
        ]
    }
}

/// Compute the breakdown for a configuration.
pub fn area_breakdown(cfg: &SharpConfig) -> AreaBreakdown {
    let banks = weight_banks_for(cfg.macs);
    let sram = Sram::new(cfg.weight_buf_bytes, banks).area_mm2()
        + Sram::new(cfg.ih_buf_bytes, (banks / 4).max(2)).area_mm2()
        + Sram::new(cfg.cell_buf_bytes, 2).area_mm2()
        + Sram::new(cfg.inter_buf_bytes, 2).area_mm2();
    // R-Add-Reduce tree + routing muxes: scales with lane count; the
    // reconfiguration muxes add <2% of this block (paper §7).
    let interconnect = 3.6e-5 * cfg.macs as f64 * (1.0 + 0.02 * cfg.padding_reconfig as u8 as f64);
    AreaBreakdown {
        compute_mm2: cfg.macs as f64 * synthesis::MAC_AREA_MM2,
        sram_mm2: sram,
        mfu_mm2: cfg.mfus as f64 * synthesis::MFU_AREA_MM2,
        interconnect_mm2: interconnect,
        controller_mm2: synthesis::CTRL_AREA_MM2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_near_table2() {
        // Table 2 totals: 101.1 / 133.3 / 227.6 / 591.9 mm^2.
        let paper = [(1024u64, 101.1), (4096, 133.3), (16384, 227.6), (65536, 591.9)];
        for (macs, total) in paper {
            let a = area_breakdown(&SharpConfig::with_macs(macs));
            let err = (a.total_mm2() - total).abs() / total;
            assert!(err < 0.10, "macs={macs}: {:.1} vs paper {total}", a.total_mm2());
        }
    }

    #[test]
    fn sram_dominates_small_compute_dominates_large() {
        let small = area_breakdown(&SharpConfig::with_macs(1024));
        assert!(small.sram_mm2 > small.compute_mm2 * 5.0);
        let large = area_breakdown(&SharpConfig::with_macs(65536));
        assert!(large.compute_mm2 > large.sram_mm2 * 3.0);
    }

    #[test]
    fn reconfig_overhead_below_half_percent_of_total() {
        // Paper: "<2% overhead in the Add-reduce module and lower than
        // 0.1% in the total area".
        let on = area_breakdown(&SharpConfig::with_macs(65536));
        let off = area_breakdown(&SharpConfig::with_macs(65536).with_reconfig(false));
        let delta = (on.total_mm2() - off.total_mm2()) / off.total_mm2();
        assert!(delta > 0.0 && delta < 0.005, "delta {delta}");
    }

    #[test]
    fn shares_sum_to_one() {
        let a = area_breakdown(&SharpConfig::with_macs(4096));
        let s: f64 = a.shares().iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}
