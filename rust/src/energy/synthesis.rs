//! Synthesis-derived constants (32 nm, 0.85 V, typical-typical).
//!
//! Every constant is either (a) stated in the paper, or (b) anchored so a
//! paper-reported aggregate reproduces — provenance in the comment. The
//! paper ran Synopsys Design Compiler with the DesignWare library; we
//! consume only the derived numbers, which is all the simulator ever used
//! in the original methodology too.

/// fp16 multiplier critical path (paper §7: "1.94 ns for the half-precision
/// multiplication, resulting in nearly 500 MHz frequency").
pub const FP16_MUL_CRIT_PATH_NS: f64 = 1.94;

/// Resulting design frequency.
pub const FREQ_HZ: f64 = 500e6;

/// Dynamic energy per MAC op (fp16 multiply + fp32 accumulate), joules.
/// Anchor: Fig. 15 gives 47.7 W total at 64K MACs with the compute unit
/// the dominant consumer (~55% -> ~26 W); 65536 lanes issuing ~90% of
/// cycles at 5e8 cyc/s -> e_mac ~= 0.8 pJ, consistent with 32 nm fp16
/// multiplier + fp32 adder energies in the literature.
pub const E_MAC_J: f64 = 0.8e-12;

/// Static (leakage) power per MAC lane, watts. Anchor: compute-unit area
/// of 7.3e-3 mm^2/MAC (Table 2) at ~11 mW/mm^2 32nm HVT logic leakage
/// (the datapath is leakage-optimized; Fig. 15's 64K total bounds it).
pub const P_MAC_LEAK_W: f64 = 0.8e-4;

/// Dynamic energy per A-MFU activation op (the exp/div chain), joules.
/// The MFU block is ~0.1 mm^2 (Table 2: 6.37 mm^2 / 64 units); its power
/// share is small and roughly constant across budgets (Fig. 15).
pub const E_ACT_J: f64 = 6.0e-12;

/// Dynamic energy per Cell-Updater pointwise op, joules.
pub const E_CU_J: f64 = 1.0e-12;

/// Leakage of the activation + cell-update block, watts (near-constant
/// across budgets per Fig. 15's "activation takes similar power").
pub const P_ACT_LEAK_W: f64 = 0.35;

/// Controller + reconfiguration logic power, watts (paper: "less than 1%
/// of the total power", and <0.1% of area).
pub const P_CTRL_W: f64 = 0.05;

/// Area of one MAC lane, mm^2. Anchor: Table 2's compute-unit rows are
/// consistent with 7.3e-3 mm^2 across all four budgets (7.48/29.9/119.7/
/// 478.8 mm^2 for 1K/4K/16K/64K).
pub const MAC_AREA_MM2: f64 = 7.3e-3;

/// Area of one MFU, mm^2 (Table 2: ~6.37 mm^2 for 64 units, constant).
pub const MFU_AREA_MM2: f64 = 0.0996;

/// Controller area, mm^2 (Table 2 bottom row, ~constant).
pub const CTRL_AREA_MM2: f64 = 0.085;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequency_follows_multiplier_critical_path() {
        let f = 1.0 / (FP16_MUL_CRIT_PATH_NS * 1e-9);
        // "nearly 500 MHz": the paper rounds 515 MHz down to 500.
        assert!(f > FREQ_HZ && f < 1.1 * FREQ_HZ);
    }

    #[test]
    fn mac_area_reproduces_table2_compute_rows() {
        // Table 2: compute-unit share x total area for each budget.
        let anchors: [(u64, f64, f64); 4] = [
            (1024, 0.074, 101.1),
            (4096, 0.224, 133.3),
            (16384, 0.526, 227.6),
            (65536, 0.809, 591.9),
        ];
        for (macs, share, total) in anchors {
            let paper = share * total;
            let model = macs as f64 * MAC_AREA_MM2;
            let err = (model - paper).abs() / paper;
            assert!(err < 0.02, "macs={macs}: model {model:.1} vs paper {paper:.1}");
        }
    }

    #[test]
    fn energies_positive_and_sane() {
        assert!(E_MAC_J > 0.0 && E_MAC_J < 1e-10);
        assert!(E_ACT_J > E_MAC_J); // a whole exp chain beats one MAC
    }
}
