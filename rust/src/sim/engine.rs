//! The simulation driver: folds a schedule over layers / directions /
//! time steps of an LSTM network and produces the cycle count, per-stage
//! activity, utilization, and memory traffic that the experiments and the
//! energy model consume.

use crate::config::{LstmConfig, SharpConfig};
use crate::sched::ScheduleKind;
use crate::sim::cell_updater::CellUpdater;
use crate::sim::memory::{self, MemTraffic};



/// Result of simulating one inference of one network on one configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    /// Total cycles for the full network inference (all layers, all steps).
    pub cycles: u64,
    /// Cycles during which the MAC array was issuing tiles.
    pub mac_issue_cycles: u64,
    /// MAC-lane-cycles doing useful multiplies (inside matrix bounds).
    pub useful_lane_cycles: u64,
    /// MAC-lane-cycles burned on padding lanes.
    pub padded_lane_cycles: u64,
    /// Exposed serial-tail cycles (dependency stalls the schedule ate).
    pub exposed_tail_cycles: u64,
    /// Activation ops executed (A-MFU activity).
    pub act_ops: u64,
    /// Cell-updater pointwise ops executed.
    pub cu_ops: u64,
    /// Memory traffic for the energy model.
    pub traffic: MemTraffic,
    /// Clock frequency this was simulated at.
    pub freq_hz: f64,
    /// Total MAC lanes of the simulated configuration.
    pub macs: u64,
}

impl SimResult {
    /// Wall-clock seconds at the simulated frequency.
    pub fn time_s(&self) -> f64 {
        self.cycles as f64 / self.freq_hz
    }

    /// Resource utilization: useful MAC work over all available lane-cycles —
    /// the quantity Fig. 12 reports.
    pub fn utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.useful_lane_cycles as f64 / (self.macs as f64 * self.cycles as f64)
    }

    /// Achieved FLOP/s (2 flops per useful MAC).
    pub fn achieved_flops(&self) -> f64 {
        2.0 * self.useful_lane_cycles as f64 / self.time_s()
    }
}

/// Simulate one inference of `model` on `cfg` under `kind` scheduling.
pub fn simulate(cfg: &SharpConfig, model: &LstmConfig, kind: ScheduleKind) -> SimResult {
    let sched = kind.schedule();
    let mut cycles = 0u64;
    let mut mac_issue = 0u64;
    let mut useful = 0u64;
    let mut padded = 0u64;
    let mut tails = 0u64;
    let mut act_ops = 0u64;
    let mut cu_ops = 0u64;
    let mut traffic = MemTraffic::default();
    let mut prev_layer_cycles = 0u64;

    let updater = CellUpdater::new(cfg);
    let gates = model.gates();
    for layer in 0..model.layers {
        let d = model.layer_input_dim(layer);
        let h = model.hidden;
        let t = model.seq_len;
        let b = model.batch;
        let s = crate::sim::pipeline::step_inputs_gated(cfg, d, h, b, gates);

        // Exposed DRAM fill for this layer's weights, overlapped with the
        // previous layer's compute. Layer 0 is preloaded (paper §9: "we
        // consider that all the synaptic weights fit on-chip for one layer
        // execution, similar to E-PUR and BrainWave"; §6.2.2 charges only
        // the initial burst, which we fold into layer transitions).
        let layer_weights = model.dirs() * gates * h * (d + h) * 2;
        let fill = if layer == 0 {
            0
        } else {
            memory::exposed_fill_cycles(cfg, layer_weights, prev_layer_cycles)
        };

        let mut layer_cycles = fill;
        for _dir in 0..model.dirs() {
            let step = sched.step(&s);
            // Steady-state steps plus the per-sequence overhead; the last
            // step's tail is never hidden (no next input MVM to overlap),
            // so charge the full Intergate-style tail once for Unfolded.
            let seq = sched.sequence_overhead(&s)
                + t.saturating_sub(1) * step.cycles
                + s.mh.cycles
                + s.mx.cycles.min(match kind {
                    ScheduleKind::Unfolded => 0, // last step has no next mx
                    _ => s.mx.cycles,
                })
                + sched.tail(&s);
            layer_cycles += seq;
            mac_issue += t * step.mac_busy;
            useful += t * (s.mx.useful_lane_cycles + s.mh.useful_lane_cycles);
            padded += t * (s.mx.padded_lane_cycles + s.mh.padded_lane_cycles);
            tails += t * step.exposed_tail;
            act_ops += t * b * model.cell.act_ops_per_elem() * h;
            cu_ops += t * b * updater.ops_per_step(h);
            for _ in 0..t {
                traffic.add(&memory::step_traffic(h, d, b));
            }
        }
        traffic.dram_bytes += layer_weights; // weights filled once per layer
        cycles += layer_cycles;
        prev_layer_cycles = layer_cycles;
    }

    SimResult {
        cycles,
        mac_issue_cycles: mac_issue,
        useful_lane_cycles: useful,
        padded_lane_cycles: padded,
        exposed_tail_cycles: tails,
        act_ops,
        cu_ops,
        traffic,
        freq_hz: cfg.freq_hz,
        macs: cfg.macs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn sim(macs: u64, h: u64, kind: ScheduleKind) -> SimResult {
        let cfg = SharpConfig::with_macs(macs);
        let model = LstmConfig::square(h);
        simulate(&cfg, &model, kind)
    }

    #[test]
    fn unfolded_fastest_everywhere() {
        for macs in presets::MAC_BUDGETS {
            for h in presets::HIDDEN_SWEEP {
                let un = sim(macs, h, ScheduleKind::Unfolded).cycles;
                for k in [
                    ScheduleKind::Sequential,
                    ScheduleKind::Batch,
                    ScheduleKind::Intergate,
                ] {
                    assert!(un <= sim(macs, h, k).cycles, "macs={macs} h={h} {k:?}");
                }
            }
        }
    }

    #[test]
    fn utilization_in_unit_interval_and_falls_with_macs() {
        let mut prev = 1.1;
        for macs in presets::MAC_BUDGETS {
            let r = sim(macs, 512, ScheduleKind::Unfolded);
            let u = r.utilization();
            assert!(u > 0.0 && u <= 1.0, "util {u}");
            assert!(u <= prev + 1e-9, "utilization should fall as MACs grow");
            prev = u;
        }
    }

    #[test]
    fn more_macs_never_meaningfully_slower() {
        // Growing the MAC array can add a few cycles per step of reduce-
        // tree fill (log2 of the wider fan-in), so allow that slack; the
        // run must never get slower beyond it.
        for h in [128u64, 340, 1024] {
            let mut prev = u64::MAX;
            for macs in presets::MAC_BUDGETS {
                let r = sim(macs, h, ScheduleKind::Unfolded);
                let slack = 8 * 25; // extra tree-fill cycles x T
                assert!(r.cycles <= prev.saturating_add(slack), "macs={macs} h={h}");
                prev = r.cycles;
            }
        }
    }

    #[test]
    fn high_utilization_at_small_budget() {
        // Fig. 12: ~98% at 1K MACs (AVG across dims), >=50% at 64K.
        let r1 = sim(1024, 512, ScheduleKind::Unfolded);
        assert!(r1.utilization() > 0.9, "1K util {}", r1.utilization());
        // At 64K with the naive fixed K=32 tile the column padding bites
        // (that is exactly why the paper reconfigures); K_opt restores it
        // — see fig12's utilization test. Here just require a floor.
        let r64 = sim(65536, 512, ScheduleKind::Unfolded);
        assert!(r64.utilization() > 0.15, "64K util {}", r64.utilization());
    }

    #[test]
    fn bidirectional_roughly_doubles_cycles() {
        let cfg = SharpConfig::with_macs(4096);
        let uni = simulate(&cfg, &LstmConfig::square(340), ScheduleKind::Unfolded);
        let mut bi_model = LstmConfig::square(340);
        bi_model.direction = crate::config::Direction::Bidirectional;
        let bi = simulate(&cfg, &bi_model, ScheduleKind::Unfolded);
        let ratio = bi.cycles as f64 / uni.cycles as f64;
        assert!((1.6..=2.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn useful_work_is_schedule_invariant() {
        let a = sim(4096, 340, ScheduleKind::Sequential);
        let b = sim(4096, 340, ScheduleKind::Unfolded);
        assert_eq!(a.useful_lane_cycles, b.useful_lane_cycles);
        assert_eq!(a.act_ops, b.act_ops);
    }

    #[test]
    fn time_scales_with_frequency() {
        let cfg = SharpConfig::with_macs(4096);
        let slow = SharpConfig::with_macs(4096).with_freq(250e6);
        let m = LstmConfig::square(256);
        let a = simulate(&cfg, &m, ScheduleKind::Unfolded);
        let b = simulate(&slow, &m, ScheduleKind::Unfolded);
        assert!(b.time_s() > 1.9 * a.time_s());
    }
}

#[cfg(test)]
mod gru_tests {
    use super::*;
    use crate::config::CellKind;

    #[test]
    fn gru_faster_than_lstm_same_dims() {
        // 3 gates instead of 4: ~25% less MVM work per step.
        let cfg = SharpConfig::with_macs(4096);
        let lstm = LstmConfig::square(512);
        let gru = LstmConfig::square(512).with_cell(CellKind::Gru);
        let cl = simulate(&cfg, &lstm, ScheduleKind::Unfolded).cycles;
        let cg = simulate(&cfg, &gru, ScheduleKind::Unfolded).cycles;
        let ratio = cg as f64 / cl as f64;
        assert!((0.65..0.9).contains(&ratio), "gru/lstm cycle ratio {ratio}");
    }

    #[test]
    fn gru_schedule_dominance_still_holds() {
        // Paper §8: the scheduling result generalizes to GRU.
        for macs in [1024u64, 65536] {
            let cfg = SharpConfig::with_macs(macs);
            let gru = LstmConfig::square(340).with_cell(CellKind::Gru);
            let un = simulate(&cfg, &gru, ScheduleKind::Unfolded).cycles;
            let ig = simulate(&cfg, &gru, ScheduleKind::Intergate).cycles;
            let sq = simulate(&cfg, &gru, ScheduleKind::Sequential).cycles;
            assert!(un <= ig && ig <= sq, "macs={macs}: {un} {ig} {sq}");
        }
    }

    #[test]
    fn gru_utilization_still_a_probability() {
        let cfg = SharpConfig::with_macs(16384);
        let gru = LstmConfig::square(750).with_cell(CellKind::Gru);
        let u = simulate(&cfg, &gru, ScheduleKind::Unfolded).utilization();
        assert!(u > 0.0 && u <= 1.0, "util {u}");
    }
}
