//! Bounded decoupling FIFOs (paper §4.1: "SHARP uses local FIFOs at all
//! stages in order to control the data-flow and also decouple the producer
//! and consumer pattern as well as computation and memory accesses").
//!
//! Used by the fine-grained pipeline validator (`pipeline::fine`) and by
//! the coordinator's internal queues; tracks occupancy statistics so stall
//! behaviour is observable.

use std::collections::VecDeque;

/// A bounded FIFO with occupancy accounting.
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    items: VecDeque<T>,
    capacity: usize,
    /// Lifetime counters.
    pub pushes: u64,
    pub pops: u64,
    pub full_rejections: u64,
    max_occupancy: usize,
}

impl<T> Fifo<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "FIFO capacity must be positive");
        Fifo {
            items: VecDeque::with_capacity(capacity),
            capacity,
            pushes: 0,
            pops: 0,
            full_rejections: 0,
            max_occupancy: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() == self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn max_occupancy(&self) -> usize {
        self.max_occupancy
    }

    /// Try to enqueue; returns the item back when full (producer stalls).
    pub fn push(&mut self, item: T) -> Result<(), T> {
        if self.is_full() {
            self.full_rejections += 1;
            return Err(item);
        }
        self.items.push_back(item);
        self.pushes += 1;
        self.max_occupancy = self.max_occupancy.max(self.items.len());
        Ok(())
    }

    /// Dequeue the oldest item (consumer stalls on None).
    pub fn pop(&mut self) -> Option<T> {
        let item = self.items.pop_front();
        if item.is_some() {
            self.pops += 1;
        }
        item
    }

    pub fn peek(&self) -> Option<&T> {
        self.items.front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut f = Fifo::new(4);
        for i in 0..4 {
            f.push(i).unwrap();
        }
        for i in 0..4 {
            assert_eq!(f.pop(), Some(i));
        }
        assert!(f.pop().is_none());
    }

    #[test]
    fn full_fifo_rejects_and_counts() {
        let mut f = Fifo::new(2);
        f.push(1).unwrap();
        f.push(2).unwrap();
        assert_eq!(f.push(3), Err(3));
        assert_eq!(f.full_rejections, 1);
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn conservation_no_loss_no_dup() {
        // Property: pushes - pops == occupancy at all times.
        let mut f = Fifo::new(8);
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..10_000 {
            if rng.next_u64() % 2 == 0 {
                let _ = f.push(rng.next_u64());
            } else {
                let _ = f.pop();
            }
            assert_eq!(f.pushes - f.pops, f.len() as u64);
        }
    }

    #[test]
    fn max_occupancy_tracks_high_water() {
        let mut f = Fifo::new(10);
        for i in 0..7 {
            f.push(i).unwrap();
        }
        for _ in 0..7 {
            f.pop();
        }
        assert_eq!(f.max_occupancy(), 7);
        assert!(f.is_empty());
    }

    #[test]
    #[should_panic]
    fn zero_capacity_panics() {
        let _ = Fifo::<u32>::new(0);
    }
}
