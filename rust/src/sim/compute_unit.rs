//! The Compute Unit (paper §4.2, Fig. 6 left): N vector-scalar multipliers
//! of width K sweeping the weight matrix one tile per cycle.

use crate::config::SharpConfig;
use crate::tile::geometry::{mvm_cost_fixed, mvm_cost_reconfig, MvmCost, TileGeometry};
use crate::tile::reconfig::Controller;

/// The MVM tile engine of one SHARP instance.
#[derive(Debug, Clone)]
pub struct ComputeUnit {
    ctl: Controller,
}

impl ComputeUnit {
    pub fn new(cfg: SharpConfig) -> Self {
        ComputeUnit {
            ctl: Controller::new(cfg),
        }
    }

    pub fn config(&self) -> &SharpConfig {
        &self.ctl.cfg
    }

    pub fn tile(&self) -> TileGeometry {
        self.ctl.body_tile()
    }

    /// Cost of one `r x c` MVM sweep under the current configuration,
    /// applying edge reconfiguration when enabled.
    pub fn mvm(&self, r: u64, c: u64) -> MvmCost {
        let tile = self.tile();
        let cands = self.ctl.edge_candidates();
        if cands.is_empty() {
            mvm_cost_fixed(tile, r, c)
        } else {
            mvm_cost_reconfig(tile, cands, r, c)
        }
    }

    /// Multiply operations actually performed for an `r x c` sweep,
    /// including padded lanes (they clock the multipliers too — the energy
    /// model charges them; this is why padding hurts energy, not just time).
    pub fn mult_ops(&self, cost: &MvmCost) -> u64 {
        cost.total_lane_cycles()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reconfig_toggle_changes_cost_on_ragged_rows() {
        // 4H = 1360 rows (EESEN) with K=256 tiles: tail of 80 rows.
        let on = ComputeUnit::new(
            SharpConfig::with_macs(4096).with_k(256).with_reconfig(true),
        );
        let off = ComputeUnit::new(
            SharpConfig::with_macs(4096).with_k(256).with_reconfig(false),
        );
        let c_on = on.mvm(1360, 680);
        let c_off = off.mvm(1360, 680);
        assert!(c_on.cycles < c_off.cycles);
        assert_eq!(c_on.useful_lane_cycles, c_off.useful_lane_cycles);
    }

    #[test]
    fn mult_ops_include_padding() {
        let cu = ComputeUnit::new(
            SharpConfig::with_macs(1024).with_k(32).with_reconfig(false),
        );
        let cost = cu.mvm(33, 33); // ragged on both axes
        assert_eq!(cu.mult_ops(&cost), cost.cycles * 1024);
        assert!(cost.padded_lane_cycles > 0);
    }

    #[test]
    fn bigger_budget_never_slower() {
        for h in [128u64, 340, 512, 1024] {
            let mut prev = u64::MAX;
            for macs in [1024u64, 4096, 16384, 65536] {
                let cu = ComputeUnit::new(SharpConfig::with_macs(macs));
                let c = cu.mvm(4 * h, 2 * h).cycles;
                assert!(c <= prev, "macs={macs} h={h}");
                prev = c;
            }
        }
    }
}
