//! R-Add-Reduce (paper §4.2, Fig. 6 right): a fully-pipelined tree adder
//! that sums the column-wise VS results into K-wide partial sums, with four
//! multiplexers tapping the last four levels to support the Fig. 7 tile
//! configurations.

use crate::config::SharpConfig;

/// Timing/geometry model of the reconfigurable add-reduce tree.
#[derive(Debug, Clone)]
pub struct AddReduce {
    /// Column-wise units whose partial vectors the tree must sum.
    pub fan_in: u64,
    /// Row-group stacking selects the tap level (Fig. 6's 4 muxes).
    pub row_groups: u64,
}

impl AddReduce {
    pub fn new(cfg: &SharpConfig) -> Self {
        AddReduce {
            fan_in: cfg.tile_cols().max(1),
            row_groups: cfg.mapping.row_groups,
        }
    }

    /// Tree depth: log2 of fan-in (paper: "maximum latency of log(N)").
    pub fn levels(&self) -> u64 {
        if self.fan_in <= 1 {
            1
        } else {
            64 - (self.fan_in - 1).leading_zeros() as u64
        }
    }

    /// Fill latency in cycles; after fill, throughput is one tile per
    /// cycle ("we pipeline all the levels of tree, resulting in a 1-cycle
    /// add-reduction if the pipeline is full").
    pub fn fill_cycles(&self) -> u64 {
        self.levels()
    }

    /// fp32 additions performed per tile (energy accounting): a binary
    /// tree over `fan_in` K-vectors does `fan_in - 1` vector adds.
    pub fn adds_per_tile(&self, k: u64) -> u64 {
        self.fan_in.saturating_sub(1) * k
    }

    /// Partial sums emitted per tile: row_groups groups of K each.
    pub fn outputs_per_tile(&self, k: u64) -> u64 {
        self.row_groups * k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SharpConfig;

    #[test]
    fn depth_is_log2_fan_in() {
        let ar = AddReduce::new(&SharpConfig::with_macs(1024).with_k(32));
        assert_eq!(ar.fan_in, 32);
        assert_eq!(ar.levels(), 5);
        assert_eq!(ar.fill_cycles(), 5);
    }

    #[test]
    fn row_stacking_shrinks_fan_in() {
        let c4 = SharpConfig::with_macs(1024).with_k(32).with_row_groups(1);
        let c1 = SharpConfig::with_macs(1024).with_k(32).with_row_groups(8);
        let (a4, a1) = (AddReduce::new(&c4), AddReduce::new(&c1));
        assert!(a1.fan_in < a4.fan_in);
        // Config1 emits 8x the partial sums of Config4 per tile (Fig. 7:
        // "we can update between 1K to 8K accumulators").
        assert_eq!(a1.outputs_per_tile(32), 8 * a4.outputs_per_tile(32));
    }

    #[test]
    fn adds_count_tree_edges() {
        let ar = AddReduce {
            fan_in: 8,
            row_groups: 1,
        };
        assert_eq!(ar.adds_per_tile(32), 7 * 32);
    }

    #[test]
    fn degenerate_single_column() {
        let ar = AddReduce {
            fan_in: 1,
            row_groups: 1,
        };
        assert_eq!(ar.levels(), 1);
        assert_eq!(ar.adds_per_tile(32), 0);
    }
}
