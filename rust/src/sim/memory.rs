//! On-chip SRAM and off-chip DRAM system (paper §4.1 and Table 1).
//!
//! Weight buffer: multi-banked SRAM interleaved so the MVM tile engine is
//! never bank-conflicted ("due to the predictable pattern of RNN
//! computation, we can easily interleave the weight matrices across
//! different memory banks"). I/H buffer works ping-pong; cell-state and
//! intermediate buffers are double-buffered scratchpads. DRAM appears only
//! in the initial per-layer weight fill, overlapped with compute except
//! for the first request's latency.

use crate::config::{LstmConfig, SharpConfig};

/// Traffic accounting for one simulated network inference.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MemTraffic {
    /// Bytes read from the weight SRAM (fp16 weights streamed to MACs).
    pub weight_sram_bytes: u64,
    /// Bytes moved through the I/H SRAM (inputs, hiddens; read + write).
    pub ih_sram_bytes: u64,
    /// Bytes through the cell-state / intermediate scratchpads.
    pub scratch_bytes: u64,
    /// Bytes filled from DRAM (weights once per layer + input stream).
    pub dram_bytes: u64,
}

impl MemTraffic {
    pub fn add(&mut self, o: &MemTraffic) {
        self.weight_sram_bytes += o.weight_sram_bytes;
        self.ih_sram_bytes += o.ih_sram_bytes;
        self.scratch_bytes += o.scratch_bytes;
        self.dram_bytes += o.dram_bytes;
    }
}

/// DRAM initial-fill latency that cannot be overlapped: the first burst
/// before compute can start (paper: "except for the initial delay to fetch
/// the memory requests... we can overlap the rest").
pub const DRAM_FIRST_BURST_NS: f64 = 200.0;

/// Memory-system fill bandwidth, scaled with the design point (Table 1:
/// "Peak Bandwidth (GB/s) 11, 44, 170, 561" for 1K..64K MACs — the paper
/// grows the memory interface with the compute budget).
pub fn dram_bw_bytes_per_s(macs: u64) -> f64 {
    match macs {
        1024 => 11e9,
        4096 => 44e9,
        16384 => 170e9,
        65536 => 561e9,
        // Off-anchor budgets (e.g. the 96K BrainWave-parity config):
        // interpolate proportionally to the MAC count.
        m => 561e9 * (m as f64 / 65536.0),
    }
}

/// Per-layer, per-direction, per-step traffic of the LSTM dataflow.
pub fn step_traffic(hidden: u64, input_dim: u64, batch: u64) -> MemTraffic {
    let h = hidden;
    let d = input_dim;
    // fp16 operand stream: the full fused gate matrix per step...
    let weight = 4 * h * (d + h) * 2;
    // x_t read, h_{t-1} read (D+H fp16), h_t write; per batch element.
    let ih = batch * ((d + h) * 2 + h * 2);
    // c read + c write + intermediate (unfolded x-MVM result 4H fp32).
    let scratch = batch * (2 * h * 4 + 4 * h * 4);
    MemTraffic {
        weight_sram_bytes: weight,
        ih_sram_bytes: ih,
        scratch_bytes: scratch,
        dram_bytes: batch * d * 2, // input features stream in once
    }
}

/// Whether one layer's weights fit the on-chip weight buffer (the paper
/// assumes they do for its benchmarks — we check instead of assuming).
pub fn layer_fits(cfg: &SharpConfig, model: &LstmConfig, layer: u64) -> bool {
    let d = model.layer_input_dim(layer);
    let bytes = model.dirs() * 4 * model.hidden * (d + model.hidden) * 2;
    bytes <= cfg.weight_buf_bytes
}

/// Cycles of exposed DRAM fill for a layer: the first burst plus whatever
/// part of the stream the previous layer's compute could not hide.
pub fn exposed_fill_cycles(
    cfg: &SharpConfig,
    layer_weight_bytes: u64,
    prev_layer_compute_cycles: u64,
) -> u64 {
    let fill_s = layer_weight_bytes as f64 / dram_bw_bytes_per_s(cfg.macs);
    let fill_cycles = (fill_s * cfg.freq_hz) as u64;
    let first_burst = (DRAM_FIRST_BURST_NS * 1e-9 * cfg.freq_hz) as u64;
    first_burst + fill_cycles.saturating_sub(prev_layer_compute_cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn step_traffic_scales_with_dims() {
        let small = step_traffic(128, 128, 1);
        let big = step_traffic(256, 256, 1);
        assert_eq!(big.weight_sram_bytes, 4 * small.weight_sram_bytes);
        assert_eq!(small.weight_sram_bytes, 4 * 128 * 256 * 2);
    }

    #[test]
    fn batch_scales_activations_not_weights() {
        let b1 = step_traffic(256, 256, 1);
        let b8 = step_traffic(256, 256, 8);
        assert_eq!(b1.weight_sram_bytes, b8.weight_sram_bytes);
        assert_eq!(b8.ih_sram_bytes, 8 * b1.ih_sram_bytes);
    }

    #[test]
    fn paper_benchmarks_fit_on_chip() {
        let cfg = crate::config::SharpConfig::with_macs(65536);
        for net in presets::table5_networks() {
            for l in 0..net.layers {
                assert!(layer_fits(&cfg, &net, l), "{} layer {l}", net.name);
            }
        }
    }

    #[test]
    fn exposed_fill_hidden_behind_long_compute() {
        let cfg = crate::config::SharpConfig::with_macs(1024);
        // 1 MB fill, previous layer ran 10M cycles: only the burst shows.
        let exp = exposed_fill_cycles(&cfg, 1 << 20, 10_000_000);
        assert_eq!(exp, (200e-9 * 500e6) as u64);
        // No previous compute: the whole stream is exposed.
        let cold = exposed_fill_cycles(&cfg, 1 << 20, 0);
        assert!(cold > exp);
    }

    #[test]
    fn dram_bw_matches_table1_anchors() {
        assert_eq!(dram_bw_bytes_per_s(1024), 11e9);
        assert_eq!(dram_bw_bytes_per_s(65536), 561e9);
        // Interpolation is monotone between anchors.
        assert!(dram_bw_bytes_per_s(96 * 1024) > dram_bw_bytes_per_s(65536));
    }
}
