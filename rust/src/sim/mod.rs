//! The SHARP cycle-level simulator (paper §7: "we developed an
//! architectural C++ cycle-accurate simulator to accurately model all the
//! pipeline stages described in Section 4" — this is that simulator, in
//! Rust, at tile granularity).
//!
//! Structure mirrors Fig. 5: `compute_unit` (N x K VS multipliers) feeds
//! `add_reduce` (pipelined reconfigurable tree), whose accumulated gate
//! groups flow through `mfu` (activation) into `cell_updater`; bounded
//! `fifo`s decouple the stages and `memory` models the SRAM/DRAM system.
//! `pipeline` derives the schedule-independent timing parameters, and
//! `engine` folds a `sched::Schedule` over layers/directions/time steps,
//! producing a `SimResult` with cycles, utilization, and the activity
//! factors the energy model consumes.
//!
//! The per-step math is closed-form at tile granularity (O(1) per step,
//! O(layers) per network); `pipeline::fine` contains a cycle-by-cycle
//! event validator used by tests to show the closed forms match an
//! explicit pipeline walk on small cases (§Perf: the closed form IS the
//! optimized hot path; the event walk is the reference).

pub mod add_reduce;
pub mod cell_updater;
pub mod compute_unit;
pub mod engine;
pub mod fifo;
pub mod memory;
pub mod mfu;
pub mod pipeline;

pub use engine::{simulate, SimResult};
pub use pipeline::{stack_pipeline_estimate, stack_step_flops, step_inputs, StackEstimate};
