//! The Activation Multi-Functional Unit (paper §4.3): a configurable chain
//! of floating-point sub-units (shift, add, divide, exponentiate) that
//! realizes sigmoid and tanh, pipelined to 1-cycle steady-state throughput.

/// The activation functions the MFU realizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Sigmoid,
    Tanh,
}

/// The micro-op sequence the MFU chains for an activation (paper eq. (1)
/// shows sigmoid as exp -> add-1 -> reciprocal).
pub fn micro_ops(act: Activation) -> &'static [&'static str] {
    match act {
        Activation::Sigmoid => &["exp", "add1", "recip"],
        // tanh(x) = 2*sigmoid(2x) - 1: shift, exp, add, recip, shift, sub.
        Activation::Tanh => &["shl1", "exp", "add1", "recip", "shl1", "sub1"],
    }
}

/// Synthesized critical-path delay of the full tanh chain (paper §4.3:
/// 29.14 ns from Synopsys DC at 32 nm), and the 500 MHz cycle time it is
/// partitioned into.
pub const TANH_CHAIN_NS: f64 = 29.14;
pub const CYCLE_NS: f64 = 2.0;

/// Pipeline stages after partitioning the chain at 1 cycle per stage —
/// this is the A-MFU fill latency the schedulers see.
pub fn pipeline_stages() -> u64 {
    (TANH_CHAIN_NS / CYCLE_NS).ceil() as u64
}

/// Activation operations per LSTM step (energy accounting): 4H gate
/// activations plus H tanh(c_t) in the Cell Updater's own A-MFU.
pub fn ops_per_step(hidden: u64) -> u64 {
    5 * hidden
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_from_synthesis_delay() {
        // ceil(29.14 / 2.0) = 15 single-cycle stages.
        assert_eq!(pipeline_stages(), 15);
    }

    #[test]
    fn sigmoid_chain_matches_paper_eq1() {
        assert_eq!(micro_ops(Activation::Sigmoid), &["exp", "add1", "recip"]);
    }

    #[test]
    fn tanh_longer_than_sigmoid() {
        assert!(micro_ops(Activation::Tanh).len() > micro_ops(Activation::Sigmoid).len());
    }

    #[test]
    fn ops_per_step_counts_all_five_activations() {
        // 4 gates + tanh(c_t), each over H elements.
        assert_eq!(ops_per_step(340), 5 * 340);
    }
}
