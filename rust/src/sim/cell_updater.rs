//! The Cell Updater (paper §4.3): consumes the four activated gate streams
//! and produces the new cell state and hidden output, "assuring that the
//! calculation of every K/4 elements of hidden outputs finishes each cycle".

use crate::config::SharpConfig;
use crate::util::ceil_div;

/// Timing/throughput model of the Cell-Updater stage.
#[derive(Debug, Clone, Copy)]
pub struct CellUpdater {
    /// VS width K: the stage emits K/4 hidden elements per cycle.
    pub k: u64,
}

/// Pipeline depth of the update datapath (multiply, add, tanh tap, mask) —
/// short relative to the A-MFU chain; fixed by the stage partitioning.
pub const PIPELINE_STAGES: u64 = 6;

impl CellUpdater {
    pub fn new(cfg: &SharpConfig) -> Self {
        CellUpdater { k: cfg.mapping.k }
    }

    /// Hidden elements produced per cycle.
    pub fn elems_per_cycle(&self) -> u64 {
        (self.k / 4).max(1)
    }

    /// Cycles to drain the update of all H cells: ceil(H / (K/4)), i.e.
    /// ceil(4H/K) for K >= 4.
    pub fn drain_cycles(&self, hidden: u64) -> u64 {
        ceil_div(hidden, self.elems_per_cycle())
    }

    /// Pointwise fp ops per step for energy accounting: per cell
    /// 3 multiplies + 2 adds (+ activations counted by the MFU model).
    pub fn ops_per_step(&self, hidden: u64) -> u64 {
        5 * hidden
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SharpConfig;

    #[test]
    fn drain_matches_4h_over_k() {
        let cu = CellUpdater { k: 32 };
        assert_eq!(cu.elems_per_cycle(), 8);
        assert_eq!(cu.drain_cycles(340), 43); // ceil(340/8)
        assert_eq!(cu.drain_cycles(512), 64);
    }

    #[test]
    fn wider_k_drains_faster() {
        let narrow = CellUpdater { k: 32 };
        let wide = CellUpdater { k: 256 };
        assert!(wide.drain_cycles(1024) < narrow.drain_cycles(1024));
    }

    #[test]
    fn from_config() {
        let cu = CellUpdater::new(&SharpConfig::with_macs(4096).with_k(128));
        assert_eq!(cu.k, 128);
        assert_eq!(cu.elems_per_cycle(), 32);
    }

    #[test]
    fn tiny_k_still_progresses() {
        let cu = CellUpdater { k: 2 };
        assert_eq!(cu.elems_per_cycle(), 1);
        assert_eq!(cu.drain_cycles(10), 10);
    }
}
