//! Schedule-independent pipeline parameters + a cycle-by-cycle validator.
//!
//! `step_inputs` derives the `sched::StepInputs` for one LSTM layer on one
//! SHARP configuration — the tile sweep costs of the input/hidden gate
//! matrices and the fill/drain latencies of the downstream stages.
//!
//! `fine` walks the pipeline cycle-by-cycle (tile issue, tree fill,
//! activation, cell-update stream) for the Intergate schedule and is used
//! by tests to validate that the closed-form step math matches an explicit
//! event walk — the closed form is the §Perf-optimized hot path, the walk
//! is its reference semantics.
//!
//! [`stack_pipeline_estimate`] lifts the same fill/drain reasoning one
//! level up: it predicts the speedup of the runtime's inter-layer step
//! pipeline ([`crate::runtime::kernel::stack`]) over layer-by-layer
//! execution for an L-deep stack, the number `benches/perf_stack.rs`
//! reports next to its measured ratio.

use crate::config::SharpConfig;
use crate::sched::StepInputs;
use crate::sim::cell_updater::{CellUpdater, PIPELINE_STAGES as CU_STAGES};
use crate::sim::compute_unit::ComputeUnit;
use crate::sim::mfu;
use crate::sim::add_reduce::AddReduce;

/// Derive the per-step timing inputs for a layer with `input_dim` inputs
/// and `hidden` units, batch `b`, under `cfg`. `gates` is the cell
/// family's gate count (4 = LSTM, 3 = GRU); the fused gate matrix is
/// `gates*H` rows tall.
///
/// Batch elements share weights: the tile engine re-sweeps the matrix per
/// batch vector (vector-scalar primitives process one vector at a time),
/// so MVM cycles scale with `b` while fills do not.
pub fn step_inputs_gated(
    cfg: &SharpConfig,
    input_dim: u64,
    hidden: u64,
    b: u64,
    gates: u64,
) -> StepInputs {
    let cu = ComputeUnit::new(cfg.clone());
    let mut mx = cu.mvm(gates * hidden, input_dim);
    let mut mh = cu.mvm(gates * hidden, hidden);
    // Re-sweep per batch element (weights stationary, vectors stream).
    mx.cycles *= b;
    mx.useful_lane_cycles *= b;
    mx.padded_lane_cycles *= b;
    mh.cycles *= b;
    mh.useful_lane_cycles *= b;
    mh.padded_lane_cycles *= b;

    let updater = CellUpdater::new(cfg);
    StepInputs {
        mx,
        mh,
        red_fill: AddReduce::new(cfg).fill_cycles(),
        act_fill: mfu::pipeline_stages(),
        // The drain also repeats per batch element, but elements pipeline:
        // only the last element's drain is exposed, so drain stays per-b=1.
        // The updater combines `gates` streams at K/gates elems per cycle.
        cu_drain: crate::util::ceil_div(gates * hidden, updater.k.max(1)),
        cu_fill: CU_STAGES,
    }
}

/// LSTM convenience wrapper (4 gates) — the common path.
pub fn step_inputs(cfg: &SharpConfig, input_dim: u64, hidden: u64, b: u64) -> StepInputs {
    step_inputs_gated(cfg, input_dim, hidden, b, 4)
}

/// Predicted cost of one stacked execution, sequential vs layer-pipelined.
///
/// Costs are in whatever unit the per-layer step costs were supplied in
/// (cycles, seconds, FLOPs-at-fixed-rate) — the [`Self::speedup`] ratio
/// is unit-free, which is what `benches/perf_stack.rs` compares measured
/// wall time against.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StackEstimate {
    /// Layer-by-layer execution: every layer runs all `T` steps before
    /// the next starts, so cost is `T * sum_l(step_l)`.
    pub sequential: f64,
    /// One worker per layer with step-granular hand-off: fill/drain
    /// exposes every layer's step once, then the steady state is paced
    /// by the slowest stage — `sum_l(step_l) + (T-1) * max_l(step_l)`.
    pub pipelined: f64,
    /// `sequential / pipelined`. Uniform stages give the ideal
    /// `L*T / (T + L - 1)` (e.g. L=3, T=16 → 2.67x); a bottleneck stage
    /// drags the estimate toward `sum / max`.
    pub speedup: f64,
}

/// Estimate the stack-level speedup of pipelining `step_costs.len()`
/// layers across workers for a `steps`-long sequence, given each layer's
/// per-step cost. Models the runtime's step-queue driver
/// ([`crate::runtime::kernel::stack`]): depth-2 queues per boundary are
/// enough to keep the bottleneck stage busy, so the classic linear
/// pipeline makespan (`fill + (T-1) * bottleneck`) is the estimate —
/// queue-depth second-order effects are below its accuracy anyway.
pub fn stack_pipeline_estimate(step_costs: &[f64], steps: usize) -> StackEstimate {
    let t = steps as f64;
    let sum: f64 = step_costs.iter().sum();
    let max = step_costs.iter().cloned().fold(0.0f64, f64::max);
    let sequential = t * sum;
    let pipelined = if steps == 0 || step_costs.is_empty() {
        0.0
    } else {
        sum + (t - 1.0) * max
    };
    let speedup = if pipelined > 0.0 {
        sequential / pipelined
    } else {
        1.0
    };
    StackEstimate {
        sequential,
        pipelined,
        speedup,
    }
}

/// Per-layer step costs for a unidirectional stack, in FLOPs — the unit
/// the runtime bench feeds [`stack_pipeline_estimate`] (host GEMM time
/// per step is FLOP-proportional at fixed batch). Layer 0 consumes the
/// model input (`d` wide); deeper layers consume the previous layer's
/// output (`proj` wide when the stack projects, else `hidden`). Each
/// step is two GEMMs (`2*(d_l + h)*g*h*b` FLOPs) plus the projection
/// GEMM (`2*h*p*b`) when present.
pub fn stack_step_flops(
    d: usize,
    hidden: usize,
    b: usize,
    gates: usize,
    proj: usize,
    layers: usize,
) -> Vec<f64> {
    let width = if proj > 0 { proj } else { hidden };
    (0..layers)
        .map(|l| {
            let d_l = if l == 0 { d } else { width };
            let gemm = 2.0 * (d_l + hidden) as f64 * (gates * hidden * b) as f64;
            let project = 2.0 * (hidden * proj * b) as f64;
            gemm + project
        })
        .collect()
}

/// Cycle-by-cycle event walk of one Intergate step (validation reference).
pub mod fine {
    use super::*;
    use crate::sim::fifo::Fifo;

    /// Walk one LSTM step under Intergate order: all gates' tiles issue
    /// round-robin; a gate-group's activation fires `act_fill` after its
    /// last column segment reduces; the cell updater consumes matched
    /// groups of all four gates at one group per cycle.
    pub fn intergate_step_cycles(s: &StepInputs) -> u64 {
        // Tiles per gate-group row: the MVM sweep interleaves the 4 gates,
        // so group g (K rows of every gate) completes after its share of
        // the full sweep. We model the issue stream explicitly.
        let total_tiles = s.mx.cycles + s.mh.cycles;
        if total_tiles == 0 {
            return 0;
        }
        let groups = s.mx.row_segments.max(1);
        let tiles_per_group = total_tiles.div_ceil(groups);

        let mut ready: Fifo<u64> = Fifo::new(groups as usize + 1);
        let mut group_done_at = Vec::with_capacity(groups as usize);
        for g in 0..groups {
            // Group g's final tile issues at...
            let last_issue = ((g + 1) * tiles_per_group).min(total_tiles);
            // ...and its activated result is ready after tree + MFU fill.
            group_done_at.push(last_issue + s.red_fill + s.act_fill);
        }
        // Cell updater: consumes one ready group per `drain/groups` cycles.
        let drain_per_group = s.cu_drain.div_ceil(groups);
        let mut cu_free_at = 0u64;
        for &done in &group_done_at {
            let start = done.max(cu_free_at);
            cu_free_at = start + drain_per_group;
            let _ = ready.push(done);
        }
        cu_free_at + s.cu_fill
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ScheduleKind;

    #[test]
    fn derives_paper_latencies() {
        let cfg = SharpConfig::with_macs(1024).with_k(32);
        let s = step_inputs(&cfg, 512, 512, 1);
        assert_eq!(s.act_fill, 15); // 29.14ns / 2ns chain
        assert_eq!(s.cu_fill, 6);
        assert_eq!(s.red_fill, 5); // log2(32 col units)
        assert_eq!(s.cu_drain, 64); // ceil(4*512/32)
        // 4H x D = 2048 x 512 with 32x32 tiles: 64 * 16 = 1024 cycles.
        assert_eq!(s.mx.cycles, 1024);
    }

    #[test]
    fn batch_scales_mvm_not_fills() {
        let cfg = SharpConfig::with_macs(4096);
        let b1 = step_inputs(&cfg, 256, 256, 1);
        let b4 = step_inputs(&cfg, 256, 256, 4);
        assert_eq!(b4.mx.cycles, 4 * b1.mx.cycles);
        assert_eq!(b4.act_fill, b1.act_fill);
        assert_eq!(b4.cu_drain, b1.cu_drain);
    }

    #[test]
    fn fine_walk_close_to_closed_form() {
        // The event walk and the closed form must agree to within the
        // pipeline fills (they model the same machine at the same
        // granularity; ties differ only in how partial groups round).
        for macs in [1024u64, 4096, 16384] {
            for h in [128u64, 340, 512, 1024] {
                let cfg = SharpConfig::with_macs(macs);
                let s = step_inputs(&cfg, h, h, 1);
                let closed = ScheduleKind::Intergate.schedule().step(&s).cycles;
                let fine = fine::intergate_step_cycles(&s);
                let slack = s.red_fill + s.act_fill + s.cu_fill + s.cu_drain;
                let diff = closed.abs_diff(fine);
                assert!(
                    diff <= slack,
                    "macs={macs} h={h}: closed={closed} fine={fine} slack={slack}"
                );
            }
        }
    }

    #[test]
    fn uniform_stack_hits_ideal_fill_drain_speedup() {
        // L=3 equal stages, T=16: speedup = L*T / (T + L - 1) = 48/18.
        let est = stack_pipeline_estimate(&[5.0, 5.0, 5.0], 16);
        assert_eq!(est.sequential, 16.0 * 15.0);
        assert_eq!(est.pipelined, 15.0 + 15.0 * 5.0);
        let ideal = 48.0 / 18.0;
        assert!((est.speedup - ideal).abs() < 1e-12, "{}", est.speedup);
        // Depth 1 pipelines into itself: no speedup, no slowdown.
        let solo = stack_pipeline_estimate(&[7.0], 16);
        assert!((solo.speedup - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bottleneck_stage_caps_stack_speedup() {
        // One stage 3x the others: steady state paces at the bottleneck,
        // so speedup approaches sum/max = 5/3 < ideal 2.67.
        let est = stack_pipeline_estimate(&[1.0, 3.0, 1.0], 1000);
        assert!(est.speedup < 5.0 / 3.0);
        assert!(est.speedup > 1.6, "{}", est.speedup);
        // Degenerate inputs do not divide by zero.
        assert_eq!(stack_pipeline_estimate(&[], 8).speedup, 1.0);
        assert_eq!(stack_pipeline_estimate(&[1.0], 0).speedup, 1.0);
    }

    #[test]
    fn stack_step_flops_tracks_layer_input_widths() {
        // d=8 h=4 g=4 b=2: layer 0 GEMMs are (8+4)-wide, deeper (4+4).
        let f = stack_step_flops(8, 4, 2, 4, 0, 3);
        assert_eq!(f.len(), 3);
        assert_eq!(f[0], 2.0 * 12.0 * 32.0);
        assert_eq!(f[1], 2.0 * 8.0 * 32.0);
        assert_eq!(f[1], f[2]);
        // Projection narrows deeper layers' input and adds its own GEMM.
        let p = stack_step_flops(8, 4, 2, 4, 2, 2);
        assert_eq!(p[1], 2.0 * 6.0 * 32.0 + 2.0 * 16.0);
        assert!(p[1] < f[1] + 2.0 * 16.0);
    }

    #[test]
    fn zero_work_is_zero() {
        let s = StepInputs {
            mx: Default::default(),
            mh: Default::default(),
            red_fill: 5,
            act_fill: 15,
            cu_drain: 8,
            cu_fill: 6,
        };
        assert_eq!(fine::intergate_step_cycles(&s), 0);
    }
}
