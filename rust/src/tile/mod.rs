//! The resizable MVM tile engine (paper §4.2, Fig. 6/7) and its
//! reconfiguration machinery (§6).
//!
//! A tile covers `rows x cols` of a weight matrix per cycle, where `rows`
//! spans the output dimension (4H for the fused gate matrix) and `cols`
//! spans the contraction dimension (D or H). Padding arises whenever the
//! matrix dimensions are not multiples of the tile (§6.1.1); dynamic
//! reconfiguration shrinks the effective K at the last row segment to
//! recover most of that waste (§6.2.1).

pub mod explore;
pub mod geometry;
pub mod reconfig;

pub use explore::{explore_k, ConfigTable, ConfigTableEntry};
pub use geometry::{MvmCost, TileGeometry};
