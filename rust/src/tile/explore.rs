//! Offline configuration exploration (paper §6.2.2).
//!
//! "We explore the configurations offline in order to determine the
//! parameters that reach the best performance for each application. This
//! generates a table with several entries, each storing the optimal
//! configuration for each LSTM's hidden dimension" — this module is that
//! offline pass. It is generic over the evaluator so the unit tests can use
//! a toy cost model while the experiments plug in the cycle simulator.

use crate::config::presets::K_RECONFIG;
use crate::config::SharpConfig;

/// One entry of the controller's preloaded configuration table.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigTableEntry {
    /// LSTM hidden dimension this entry is keyed by.
    pub hidden: u64,
    /// Chosen VS width K.
    pub k: u64,
    /// Chosen row-group stacking (Fig. 7 config).
    pub row_groups: u64,
    /// Evaluated cost (cycles) of the chosen configuration.
    pub cycles: u64,
}

/// The per-model configuration table preloaded into SHARP's on-chip memory.
#[derive(Debug, Clone, Default)]
pub struct ConfigTable {
    pub entries: Vec<ConfigTableEntry>,
}

impl ConfigTable {
    /// Look up the entry for a hidden dimension (exact match).
    pub fn lookup(&self, hidden: u64) -> Option<&ConfigTableEntry> {
        self.entries.iter().find(|e| e.hidden == hidden)
    }
}

/// Explore K (and row-group stacking) for one hidden dimension under a
/// fixed MAC budget; returns the best entry by evaluated cycles.
///
/// `eval` receives a fully-formed `SharpConfig` and returns its cost in
/// cycles for the workload being optimized.
pub fn explore_k<F: FnMut(&SharpConfig) -> u64>(
    base: &SharpConfig,
    hidden: u64,
    ks: &[u64],
    mut eval: F,
) -> ConfigTableEntry {
    let mut best: Option<ConfigTableEntry> = None;
    for &k in ks {
        if k > base.macs {
            continue;
        }
        // Row-group stackings realizable with N = MACs/K units; the paper's
        // four configs stack 1/2/4/8 groups.
        for g in [1u64, 2, 4, 8] {
            let cfg = base.clone().with_k(k).with_row_groups(g);
            if cfg.n_vs() < g || cfg.tile_cols() == 0 {
                continue;
            }
            let cycles = eval(&cfg);
            let better = match &best {
                None => true,
                Some(b) => cycles < b.cycles,
            };
            if better {
                best = Some(ConfigTableEntry {
                    hidden,
                    k,
                    row_groups: g,
                    cycles,
                });
            }
        }
    }
    best.expect("at least one K candidate must fit the MAC budget")
}

/// Build the whole configuration table for a set of hidden dims, using the
/// hardware-realizable K set (base-32 fusion: 32..256).
pub fn build_table<F: FnMut(&SharpConfig, u64) -> u64>(
    base: &SharpConfig,
    hiddens: &[u64],
    mut eval: F,
) -> ConfigTable {
    let entries = hiddens
        .iter()
        .map(|&h| explore_k(base, h, &K_RECONFIG, |cfg| eval(cfg, h)))
        .collect();
    ConfigTable { entries }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_minimum_cost_k() {
        let base = SharpConfig::with_macs(4096);
        // Toy evaluator: prefer K == 128.
        let entry = explore_k(&base, 512, &[32, 64, 128, 256], |cfg| {
            (cfg.mapping.k as i64 - 128).unsigned_abs() + 100
        });
        assert_eq!(entry.k, 128);
        assert_eq!(entry.cycles, 100);
    }

    #[test]
    fn skips_k_larger_than_budget() {
        let base = SharpConfig::with_macs(64);
        let entry = explore_k(&base, 128, &[32, 512], |_| 1);
        assert_eq!(entry.k, 32);
    }

    #[test]
    fn table_covers_all_dims() {
        let base = SharpConfig::with_macs(1024);
        let table = build_table(&base, &[128, 256, 512], |cfg, h| {
            cfg.mapping.k + h // arbitrary deterministic cost
        });
        assert_eq!(table.entries.len(), 3);
        assert!(table.lookup(256).is_some());
        assert!(table.lookup(999).is_none());
        // The toy cost is minimized by the smallest K.
        assert!(table.entries.iter().all(|e| e.k == 32));
    }
}
