//! Tile geometry and MVM sweep cost: how many cycles and how much padding
//! waste a `rows x cols` tile incurs sweeping an `R x C` weight matrix.

use crate::config::SharpConfig;
use crate::util::ceil_div;

/// A concrete tile shape (one of the Fig. 7 configurations, or a
/// reconfigured edge tile).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileGeometry {
    /// Output rows covered per cycle (row_groups * K).
    pub rows: u64,
    /// Contraction columns covered per cycle (N / row_groups).
    pub cols: u64,
}

impl TileGeometry {
    pub fn of(cfg: &SharpConfig) -> Self {
        TileGeometry {
            rows: cfg.tile_rows(),
            cols: cfg.tile_cols(),
        }
    }

    /// Explicit geometry. Besides the simulator's VS-unit tiles, the
    /// runtime execution planner (`runtime::plan::cost`) scores its
    /// `mr x nr` register tiles through this same cost arithmetic — one
    /// cost model, two consumers.
    pub fn new(rows: u64, cols: u64) -> Self {
        TileGeometry { rows, cols }
    }

    /// Total multiplier lanes this tile occupies.
    pub fn lanes(&self) -> u64 {
        self.rows * self.cols
    }
}

/// Cost of sweeping one MVM with a tile engine.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct MvmCost {
    /// Issue cycles (one tile dispatched per cycle, fully pipelined).
    pub cycles: u64,
    /// MAC-lane-cycles actually useful (inside the matrix).
    pub useful_lane_cycles: u64,
    /// MAC-lane-cycles wasted on padding lanes (outside the matrix).
    pub padded_lane_cycles: u64,
    /// Number of row segments (completion granularity seen by the A-MFU).
    pub row_segments: u64,
}

impl MvmCost {
    pub fn total_lane_cycles(&self) -> u64 {
        self.useful_lane_cycles + self.padded_lane_cycles
    }

    /// MAC-lane utilization of this sweep.
    pub fn lane_utilization(&self) -> f64 {
        let t = self.total_lane_cycles();
        if t == 0 {
            0.0
        } else {
            self.useful_lane_cycles as f64 / t as f64
        }
    }

    pub fn add(&mut self, other: &MvmCost) {
        self.cycles += other.cycles;
        self.useful_lane_cycles += other.useful_lane_cycles;
        self.padded_lane_cycles += other.padded_lane_cycles;
        self.row_segments += other.row_segments;
    }

    /// This sweep repeated `times` (e.g. one recurrent MVM per timestep,
    /// or one output sweep per contraction step in the runtime planner's
    /// GEMM accounting).
    pub fn scale(&self, times: u64) -> MvmCost {
        MvmCost {
            cycles: self.cycles * times,
            useful_lane_cycles: self.useful_lane_cycles * times,
            padded_lane_cycles: self.padded_lane_cycles * times,
            row_segments: self.row_segments * times,
        }
    }
}

/// Sweep an `r x c` matrix with a fixed tile (no edge reconfiguration).
///
/// Padding model (§6.1.1): every issued tile occupies all `rows*cols`
/// lanes; lanes that overhang the matrix edge do no useful work but still
/// burn the cycle.
pub fn mvm_cost_fixed(tile: TileGeometry, r: u64, c: u64) -> MvmCost {
    if r == 0 || c == 0 {
        return MvmCost::default();
    }
    let rs = ceil_div(r, tile.rows);
    let cs = ceil_div(c, tile.cols);
    let cycles = rs * cs;
    let useful = r * c;
    let issued = cycles * tile.lanes();
    MvmCost {
        cycles,
        useful_lane_cycles: useful,
        padded_lane_cycles: issued - useful,
        row_segments: rs,
    }
}

/// Sweep with dynamic padding reconfiguration (§6.2.1): when the last row
/// segment does not fill the tile, the controller re-fuses the base VS
/// units into the config whose `rows` gets "as close as possible to the
/// remaining rows", widening `cols` with the freed lanes. The candidate
/// edge tiles must conserve total lanes (same multipliers, re-mapped).
pub fn mvm_cost_reconfig(
    tile: TileGeometry,
    candidate_rows: &[u64],
    r: u64,
    c: u64,
) -> MvmCost {
    if r == 0 || c == 0 {
        return MvmCost::default();
    }
    let full_rows_segments = r / tile.rows;
    let tail_rows = r % tile.rows;
    // Body: full segments with the configured tile.
    let mut cost = if full_rows_segments > 0 {
        mvm_cost_fixed(tile, full_rows_segments * tile.rows, c)
    } else {
        MvmCost::default()
    };
    if tail_rows == 0 {
        return cost;
    }
    // Edge: pick the candidate with the fewest cycles (the controller's
    // offline table stores this choice; ties favor fewer padded lanes).
    let lanes = tile.lanes();
    let mut best: Option<MvmCost> = None;
    for &cr in candidate_rows.iter().filter(|&&cr| cr <= lanes) {
        let edge_tile = TileGeometry {
            rows: cr,
            cols: (lanes / cr).max(1),
        };
        let cand = mvm_cost_fixed(edge_tile, tail_rows, c);
        let better = match &best {
            None => true,
            Some(b) => {
                cand.cycles < b.cycles
                    || (cand.cycles == b.cycles
                        && cand.padded_lane_cycles < b.padded_lane_cycles)
            }
        };
        if better {
            best = Some(cand);
        }
    }
    // Fall back to the fixed tile if no candidate fits.
    let edge = best.unwrap_or_else(|| mvm_cost_fixed(tile, tail_rows, c));
    cost.add(&edge);
    cost
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: TileGeometry = TileGeometry { rows: 32, cols: 32 };

    #[test]
    fn exact_fit_has_no_padding() {
        let c = mvm_cost_fixed(T, 128, 64);
        assert_eq!(c.cycles, 4 * 2);
        assert_eq!(c.padded_lane_cycles, 0);
        assert_eq!(c.useful_lane_cycles, 128 * 64);
        assert!((c.lane_utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn overhang_charges_padding() {
        let c = mvm_cost_fixed(T, 33, 32); // one extra row -> 2 row segs
        assert_eq!(c.cycles, 2);
        assert_eq!(c.useful_lane_cycles, 33 * 32);
        assert_eq!(c.padded_lane_cycles, 2 * 1024 - 33 * 32);
    }

    #[test]
    fn cost_covers_matrix_exactly() {
        // Invariant: useful lane-cycles always equal r*c.
        for r in [1, 31, 32, 33, 340, 4096] {
            for c in [1, 31, 32, 33, 680] {
                let cost = mvm_cost_fixed(T, r, c);
                assert_eq!(cost.useful_lane_cycles, r * c, "r={r} c={c}");
            }
        }
    }

    #[test]
    fn reconfig_never_slower() {
        let cands = [32, 64, 128, 256];
        for r in [33, 100, 340, 1360, 2048, 4100] {
            for c in [64, 340, 1024] {
                let fixed = mvm_cost_fixed(TileGeometry { rows: 256, cols: 16 }, r, c);
                let rec =
                    mvm_cost_reconfig(TileGeometry { rows: 256, cols: 16 }, &cands, r, c);
                assert!(rec.cycles <= fixed.cycles, "r={r} c={c}");
                assert_eq!(rec.useful_lane_cycles, fixed.useful_lane_cycles);
            }
        }
    }

    #[test]
    fn reconfig_noop_when_multiple() {
        // h=512 case of Fig. 10: 4H = 2048 is a multiple of 256 -> no gain.
        let tile = TileGeometry { rows: 256, cols: 16 };
        let fixed = mvm_cost_fixed(tile, 2048, 1024);
        let rec = mvm_cost_reconfig(tile, &[32, 64, 128, 256], 2048, 1024);
        assert_eq!(fixed, rec);
    }

    #[test]
    fn reconfig_speeds_up_ragged_edge() {
        // 4H = 1360 (EESEN h=340) with a 256-row tile: tail of 80 rows.
        let tile = TileGeometry { rows: 256, cols: 16 };
        let fixed = mvm_cost_fixed(tile, 1360, 680);
        let rec = mvm_cost_reconfig(tile, &[32, 64, 128, 256], 1360, 680);
        assert!(rec.cycles < fixed.cycles);
    }

    #[test]
    fn scale_multiplies_every_field() {
        let c = mvm_cost_fixed(TileGeometry::new(32, 32), 33, 32);
        let s = c.scale(5);
        assert_eq!(s.cycles, 5 * c.cycles);
        assert_eq!(s.useful_lane_cycles, 5 * c.useful_lane_cycles);
        assert_eq!(s.padded_lane_cycles, 5 * c.padded_lane_cycles);
        assert_eq!(s.row_segments, 5 * c.row_segments);
        assert_eq!(c.scale(0), MvmCost::default());
    }

    #[test]
    fn zero_dims_are_free() {
        assert_eq!(mvm_cost_fixed(T, 0, 10).cycles, 0);
        assert_eq!(mvm_cost_reconfig(T, &[32], 10, 0).cycles, 0);
    }
}
