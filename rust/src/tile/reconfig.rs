//! The reconfiguration controller (paper §6.2).
//!
//! At runtime the controller does only two cheap things (the paper stresses
//! reconfiguration has negligible runtime cost): (1) before each LSTM layer
//! it looks up the layer's optimal tile configuration in a small preloaded
//! table, and (2) at the last row segment of each MVM it swaps the tree-
//! adder multiplexers to the edge configuration. The expensive part — the
//! offline exploration that *fills* the table — lives in `tile::explore`.

use crate::config::presets::K_RECONFIG;
use crate::config::SharpConfig;

use super::geometry::TileGeometry;

/// The runtime reconfiguration state for one accelerator instance.
#[derive(Debug, Clone)]
pub struct Controller {
    /// Base configuration (Table 1 design point).
    pub cfg: SharpConfig,
    /// Edge-tile row candidates realizable by fusing base-32 VS units.
    pub edge_rows: Vec<u64>,
}

impl Controller {
    pub fn new(cfg: SharpConfig) -> Self {
        // Candidate edge tiles: K in {32..256} times the current row-group
        // stacking — all realizable by remuxing the last 4 tree levels.
        let g = cfg.mapping.row_groups;
        let edge_rows = K_RECONFIG.iter().map(|&k| k * g).collect();
        Controller { cfg, edge_rows }
    }

    /// Tile geometry for the body of an MVM sweep.
    pub fn body_tile(&self) -> TileGeometry {
        TileGeometry::of(&self.cfg)
    }

    /// Candidate edge-tile rows (empty when reconfiguration is disabled,
    /// which makes `mvm_cost_reconfig` degrade to the fixed path).
    pub fn edge_candidates(&self) -> &[u64] {
        if self.cfg.padding_reconfig {
            &self.edge_rows
        } else {
            &[]
        }
    }

    /// The 4 multiplexer settings of R-Add-Reduce (Fig. 6): which of the
    /// last four tree levels is tapped for a given row-group stacking.
    /// Returns the tree level counted from the final adder (0 = full sum).
    pub fn mux_level(&self, row_groups: u64) -> u32 {
        // Config4 (1 group) taps the final level; Config1 (8 groups) taps
        // the 4th-last level (LogN - 3 in the paper's notation).
        row_groups.next_power_of_two().trailing_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_track_row_groups() {
        let ctl = Controller::new(SharpConfig::with_macs(4096).with_row_groups(2));
        assert_eq!(ctl.edge_rows, vec![64, 128, 256, 512]);
    }

    #[test]
    fn disabled_reconfig_has_no_candidates() {
        let ctl = Controller::new(SharpConfig::with_macs(4096).with_reconfig(false));
        assert!(ctl.edge_candidates().is_empty());
    }

    #[test]
    fn mux_levels_match_fig6() {
        let ctl = Controller::new(SharpConfig::with_macs(4096));
        assert_eq!(ctl.mux_level(1), 0); // Config4: final adder output
        assert_eq!(ctl.mux_level(2), 1);
        assert_eq!(ctl.mux_level(4), 2);
        assert_eq!(ctl.mux_level(8), 3); // Config1: LogN-3 tap
    }
}
