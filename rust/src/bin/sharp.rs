//! `sharp` — CLI for the SHARP reproduction.
//!
//! Subcommands (hand-rolled parsing; the offline registry has no clap):
//!   sharp list                   list the 13 paper exhibit ids
//!   sharp figure <id>            regenerate one paper exhibit (fig01..fig15)
//!   sharp table <id>             regenerate one paper table (table2/4/6)
//!   sharp all [--json <dir>]     every exhibit in paper order (+ JSON dump)
//!   sharp simulate [opts]        run the cycle simulator on one design point
//!   sharp explore [opts]         offline K_opt exploration (controller table)
//!   sharp infer <artifact>       run one artifact against its goldens
//!   sharp serve [opts]           replay a synthetic trace through the
//!                                dispatcher + worker pool (--workers N,
//!                                --hidden H[,H2], --streaming sessions
//!                                with fused steps, --fused-lanes L,
//!                                --json FILE metrics snapshot), or
//!                                --listen ADDR to serve it over TCP
//!   sharp loadgen [opts]         drive a TCP server: concurrent
//!                                connections, retry with capped jittered
//!                                backoff, session resume on reconnect
//!   sharp drain [opts]           control plane over TCP: graceful drain
//!                                (also --cmd health|metrics)
//!   sharp plan [opts]            show the execution planner's candidates
//!                                and choice for a model shape (--d
//!                                --hidden --batch --seq | --artifact)
//!   sharp artifacts              list AOT artifacts in the manifest

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use sharp::config::presets::{budget_label, K_RECONFIG};
use sharp::config::{LstmConfig, SharpConfig};
use sharp::coordinator::net::{Listener, NetClient, NetConfig, NetRequest, RetryPolicy};
use sharp::coordinator::{FaultPlan, InferenceRequest, OverloadPolicy, Server, ServerConfig};
use sharp::error::{anyhow, bail, ensure, Result};
use sharp::experiments;
use sharp::report;
use sharp::runtime::plan::{cost, tuner};
use sharp::runtime::{
    literal::max_abs_diff, ArtifactStore, Dtype, Isa, KernelGeometry, LstmExecutable, ModelDims,
    PlanMode, RuntimeConfig, StackExecutable,
};
use sharp::sched::ScheduleKind;
use sharp::sim::{simulate, stack_pipeline_estimate, stack_step_flops};
use sharp::tile::explore_k;
use sharp::util::json::{self, Json};
use sharp::util::rng::Rng;
use sharp::util::stats::Samples;
use sharp::util::table::Table;
use sharp::workloads::{TraceConfig, TraceKind};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            // A following "--x" means this flag is a bare switch
            // (e.g. `--streaming --workers 4` must not eat `--workers`).
            match args.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    out.insert(key.to_string(), v.clone());
                    i += 2;
                }
                _ => {
                    out.insert(key.to_string(), String::new());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    out
}

fn flag_u64(flags: &HashMap<String, String>, key: &str, default: u64) -> u64 {
    flags
        .get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Comma-separated usize list flag (e.g. `--hidden 64,256`).
fn flag_usize_list(flags: &HashMap<String, String>, key: &str, default: &str) -> Vec<usize> {
    flags
        .get(key)
        .map(String::as_str)
        .filter(|s| !s.is_empty())
        .unwrap_or(default)
        .split(',')
        .filter_map(|t| t.trim().parse().ok())
        .collect()
}

/// Parse `--plan auto|calibrated|fixed[:MRxNR]` into a [`PlanMode`].
fn parse_plan_mode(s: &str) -> Result<PlanMode> {
    match s {
        "" | "auto" => Ok(PlanMode::Auto),
        "calibrated" => Ok(PlanMode::Calibrated),
        "fixed" => Ok(PlanMode::Fixed(KernelGeometry::fixed_default())),
        other => {
            let spec = other.strip_prefix("fixed:").ok_or_else(|| {
                anyhow!("--plan wants auto|calibrated|fixed[:MRxNR], got '{other}'")
            })?;
            let (mr, nr) = spec
                .split_once('x')
                .ok_or_else(|| anyhow!("--plan fixed:MRxNR (e.g. fixed:4x16), got '{spec}'"))?;
            let mr: usize = mr.parse().map_err(|_| anyhow!("bad MR '{mr}'"))?;
            let nr: usize = nr.parse().map_err(|_| anyhow!("bad NR '{nr}'"))?;
            Ok(PlanMode::Fixed(KernelGeometry::new(mr, nr)?))
        }
    }
}

/// The runtime knobs shared by `infer`/`serve`/`plan`: `--threads T`,
/// `--plan auto|calibrated|fixed[:MRxNR]`, `--kernel scalar|avx2|neon`
/// (default: the `SHARP_FORCE_KERNEL` environment pin, else the best
/// detected ISA; forcing an unavailable ISA fails loudly at bind), and
/// `--quant f32|int8` (weight dtype; int8 quantizes per gate at bind
/// and fuses the dequant into the activation stage).
fn parse_runtime(flags: &HashMap<String, String>) -> Result<RuntimeConfig> {
    Ok(RuntimeConfig {
        threads: flag_u64(flags, "threads", 1) as usize,
        plan: parse_plan_mode(flags.get("plan").map(String::as_str).unwrap_or("auto"))?,
        force_kernel: match flags.get("kernel").map(String::as_str) {
            None | Some("") => None,
            Some(spec) => Some(Isa::parse(spec)?),
        },
        dtype: match flags.get("quant").map(String::as_str) {
            None | Some("") => Dtype::F32,
            Some(spec) => Dtype::parse(spec)?,
        },
    })
}

fn cmd_list() -> i32 {
    println!("paper exhibits ({}):", experiments::ALL_IDS.len());
    for id in experiments::ALL_IDS {
        println!("  {id}");
    }
    println!("render one with `sharp figure <id>` (or `sharp table <id>`).");
    0
}

fn cmd_exhibit(id: &str) -> i32 {
    match experiments::run(id) {
        Some(e) => {
            println!("{}", e.render());
            0
        }
        None => {
            eprintln!("unknown exhibit '{id}'; known: {:?}", experiments::ALL_IDS);
            2
        }
    }
}

fn cmd_all(flags: &HashMap<String, String>) -> i32 {
    let exhibits = experiments::run_all();
    for e in &exhibits {
        println!("{}", e.render());
    }
    println!("{}", report::summary(&exhibits));
    if let Some(dir) = flags.get("json") {
        if dir.is_empty() {
            eprintln!("--json needs a directory argument");
            return 2;
        }
        if let Err(e) = write_json_dump(Path::new(dir), &exhibits) {
            eprintln!("writing JSON dump: {e:#}");
            return 1;
        }
        println!("JSON dump written to {dir}/");
    }
    0
}

/// Write `<dir>/<id>.json` per exhibit plus `<dir>/summary.txt`.
fn write_json_dump(dir: &Path, exhibits: &[sharp::report::Exhibit]) -> Result<()> {
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow!("create {dir:?}: {e}"))?;
    for e in exhibits {
        let path = dir.join(format!("{}.json", e.id));
        std::fs::write(&path, json::write(&e.to_json()))
            .map_err(|err| anyhow!("write {path:?}: {err}"))?;
    }
    std::fs::write(dir.join("summary.txt"), report::summary(exhibits))
        .map_err(|e| anyhow!("write summary.txt: {e}"))?;
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> i32 {
    let macs = flag_u64(flags, "macs", 4096);
    let hidden = flag_u64(flags, "hidden", 512);
    let seq = flag_u64(flags, "seq", 25);
    let k = flag_u64(flags, "k", 32);
    let sched = match flags.get("sched").map(String::as_str) {
        Some("sequential") => ScheduleKind::Sequential,
        Some("batch") => ScheduleKind::Batch,
        Some("intergate") => ScheduleKind::Intergate,
        _ => ScheduleKind::Unfolded,
    };
    let cfg = SharpConfig::with_macs(macs).with_k(k);
    let model = LstmConfig::square(hidden).with_seq_len(seq);
    let r = simulate(&cfg, &model, sched);
    let p = sharp::energy::power_report(&cfg, &r);
    println!(
        "design: {} MACs, K={k}, {} schedule | model: h={hidden} T={seq}",
        budget_label(macs),
        sched.name()
    );
    println!(
        "cycles={} time={:.2}us utilization={:.1}% achieved={:.2} GFLOPS",
        r.cycles,
        r.time_s() * 1e6,
        r.utilization() * 100.0,
        r.achieved_flops() / 1e9
    );
    println!(
        "power={:.2}W energy={:.2}uJ efficiency={:.1} GFLOPS/W",
        p.total_w(),
        p.energy_j() * 1e6,
        p.flops_per_watt(r.achieved_flops()) / 1e9
    );
    0
}

fn cmd_explore(flags: &HashMap<String, String>) -> i32 {
    let macs = flag_u64(flags, "macs", 4096);
    let hidden = flag_u64(flags, "hidden", 512);
    let seq = flag_u64(flags, "seq", 25);
    let model = LstmConfig::square(hidden).with_seq_len(seq);
    let base = SharpConfig::with_macs(macs);
    println!(
        "offline exploration (paper §6.2.2): h={hidden} T={seq} @ {}",
        budget_label(macs)
    );
    let entry = explore_k(&base, hidden, &K_RECONFIG, |cfg| {
        let c = simulate(cfg, &model, ScheduleKind::Unfolded).cycles;
        println!(
            "  K={:<4} groups={} tile={}x{}: {} cycles",
            cfg.mapping.k,
            cfg.mapping.row_groups,
            cfg.tile_rows(),
            cfg.tile_cols(),
            c
        );
        c
    });
    println!(
        "-> controller table entry: K={} row_groups={} ({} cycles)",
        entry.k, entry.row_groups, entry.cycles
    );
    0
}

fn cmd_artifacts() -> i32 {
    match ArtifactStore::open_default() {
        Ok(store) => {
            println!(
                "artifacts in {:?} (gate order {}):",
                store.dir, store.manifest.gate_order
            );
            for e in &store.manifest.entries {
                println!(
                    "  {:<18} kind={:<4} T={:<3} B={} D={:<4} H={:<4} ({})",
                    e.name, e.kind, e.t, e.b, e.d, e.h, e.hlo_file
                );
            }
            0
        }
        Err(e) => {
            eprintln!("cannot open artifacts: {e:#}");
            1
        }
    }
}

/// The efficiency line `infer --quant` appends: time the bound
/// executable (measured GFLOP/s on this host) and put the energy
/// model's figure for the same model shape next to it (estimated
/// GFLOPS/W at the default 4096-MAC design point) — the runtime
/// consumer of `energy::power`.
fn perf_energy_line(measured_gflops: f64, hidden: u64, seq: u64, dtype: Dtype) -> String {
    let cfg = SharpConfig::with_macs(4096);
    let model = LstmConfig::square(hidden).with_seq_len(seq.max(1));
    let r = simulate(&cfg, &model, ScheduleKind::Unfolded);
    let p = sharp::energy::power_report(&cfg, &r);
    format!(
        "{}: measured {:.2} GFLOP/s | estimated {:.1} GFLOPS/W ({} accel @ {} schedule)",
        dtype.name(),
        measured_gflops,
        p.flops_per_watt(r.achieved_flops()) / 1e9,
        budget_label(4096),
        ScheduleKind::Unfolded.name()
    )
}

/// Median-free quick timing: warm once, then average a few runs.
fn time_runs<F: FnMut()>(mut run: F) -> f64 {
    run();
    let reps = 5;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        run();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn cmd_infer(name: &str, flags: &HashMap<String, String>) -> i32 {
    let run = || -> Result<(f32, Vec<String>, f32, Option<String>)> {
        let store = ArtifactStore::open_default()?;
        let rt = parse_runtime(flags)?;
        // Int8 trades bits for speed: the golden gate widens to the
        // documented quantization budget (DESIGN.md §12) instead of the
        // f32 path's near-exact 1e-4.
        let dtype = rt.dtype;
        let tol = match dtype {
            Dtype::Int8 => 5e-2,
            Dtype::F32 => 1e-4,
        };
        let want_perf = flags.contains_key("quant");
        let entry = store
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let input = |n: &str| -> Result<Vec<f32>> {
            let m = entry
                .inputs
                .iter()
                .find(|i| i.name == n)
                .ok_or_else(|| anyhow!("missing input {n}"))?;
            store.golden(m)
        };
        if entry.is_stacked() {
            // Stacked entries bind through the stack executable: one
            // plan per layer (layer 0 sees D-wide GEMMs, deeper layers
            // the previous layer's output width), rendered one row per
            // layer like the serve metrics' per-layer plan keys.
            let exe = StackExecutable::from_store_goldens_with(&store, name, rt)?;
            let plans = exe
                .layer_plans()
                .iter()
                .enumerate()
                .map(|(l, p)| format!("layer{l}: {}", p.describe()))
                .collect();
            let xs = input("xs")?;
            let (mut h0, mut c0) = exe.zero_state();
            if let Ok(v) = input("h0") {
                h0 = v;
            }
            if let Ok(v) = input("c0") {
                c0 = v;
            }
            let out = exe.run(&xs, &h0, &c0)?;
            // Stacked goldens are optional (synthetic stacks ship none);
            // with none present a successful bound run is the smoke.
            let diff = if entry.outputs.len() >= 2 {
                let golden_h = store.golden(&entry.outputs[entry.outputs.len() - 2])?;
                max_abs_diff(&out.h_t, &golden_h)
            } else {
                0.0
            };
            let perf = if want_perf {
                let gates = if entry.kind.starts_with("gru") { 3 } else { 4 };
                let flops: f64 = stack_step_flops(
                    entry.d,
                    entry.h,
                    entry.b,
                    gates,
                    entry.proj,
                    entry.layers,
                )
                .iter()
                .sum::<f64>()
                    * entry.t as f64;
                let mut sout = sharp::runtime::StackOutput::default();
                let secs = time_runs(|| {
                    let _ = exe.run_into(&xs, &h0, &c0, &mut sout);
                });
                Some(perf_energy_line(
                    flops / secs / 1e9,
                    entry.h as u64,
                    entry.t as u64,
                    dtype,
                ))
            } else {
                None
            };
            return Ok((diff, plans, tol, perf));
        }
        let exe = LstmExecutable::from_store_goldens_with(&store, name, rt)?;
        let plan = exe.plan().describe();
        let xs = input(if entry.kind.ends_with("seq") { "xs" } else { "x" })?;
        let h0 = input("h0")?;
        let c0 = if entry.kind.starts_with("gru") {
            vec![0.0; h0.len()] // GRU: no cell state (ignored by run)
        } else {
            input("c0")?
        };
        let out = exe.run(&xs, &h0, &c0)?;
        let golden_h = store.golden(&entry.outputs[entry.outputs.len() - 2])?;
        let perf = if want_perf {
            let gates = if entry.kind.starts_with("gru") { 3 } else { 4 };
            let steps = if entry.kind.ends_with("seq") { entry.t } else { 1 };
            let flops: f64 = stack_step_flops(entry.d, entry.h, entry.b, gates, 0, 1)
                .iter()
                .sum::<f64>()
                * steps as f64;
            let mut buf = sharp::runtime::LstmOutput::default();
            let secs = time_runs(|| {
                let _ = exe.run_into(&xs, &h0, &c0, &mut buf);
            });
            Some(perf_energy_line(
                flops / secs / 1e9,
                entry.h as u64,
                steps as u64,
                dtype,
            ))
        } else {
            None
        };
        Ok((max_abs_diff(&out.h_t, &golden_h), vec![plan], tol, perf))
    };
    match run() {
        Ok((diff, plans, tol, perf)) => {
            match plans.as_slice() {
                [one] => println!("{name}: plan {one}, max |h_t - golden| = {diff:.3e}"),
                many => {
                    println!("{name}: {} layers, max |h_t - golden| = {diff:.3e}", many.len());
                    for p in many {
                        println!("  {p}");
                    }
                }
            }
            if let Some(line) = perf {
                println!("{line}");
            }
            if diff < tol {
                println!("PASS");
                0
            } else {
                println!("FAIL");
                1
            }
        }
        Err(e) => {
            eprintln!("infer failed: {e:#}");
            1
        }
    }
}

/// The stacked-model axes `sharp plan` resolves alongside the base
/// dims: depth, direction count, and projection width. All default to
/// the single-layer case, which keeps the classic candidate-table path.
struct StackSpec {
    layers: usize,
    bidirectional: bool,
    proj: usize,
}

impl StackSpec {
    fn is_stacked(&self) -> bool {
        self.layers > 1 || self.bidirectional || self.proj > 0
    }

    /// Input width of layer `l` (mirrors `ManifestEntry::layer_input_dim`).
    fn layer_input_dim(&self, l: usize, d: usize, h: usize) -> usize {
        if l == 0 {
            d
        } else {
            let w = if self.proj > 0 { self.proj } else { h };
            w * if self.bidirectional { 2 } else { 1 }
        }
    }
}

/// Resolve the model shape `sharp plan` plans for: an artifact by name
/// (manifest dims + its stacked axes) or explicit
/// `--hidden/--d/--batch/--seq/--kind` with `--layers/--bi/--proj`.
fn plan_dims(flags: &HashMap<String, String>) -> Result<(ModelDims, StackSpec)> {
    if let Some(name) = flags.get("artifact") {
        ensure!(!name.is_empty(), "--artifact needs a name");
        let store = ArtifactStore::open_default()?;
        let e = store
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        // THE single kind -> dims mapping, shared with the bind path.
        Ok((
            ModelDims::of_entry(e),
            StackSpec {
                layers: e.layers,
                bidirectional: e.bidirectional,
                proj: e.proj,
            },
        ))
    } else {
        let h = flag_u64(flags, "hidden", 0) as usize;
        ensure!(h > 0, "plan needs --hidden H (or --artifact NAME)");
        Ok((
            ModelDims {
                d: flag_u64(flags, "d", h as u64) as usize,
                h,
                b: flag_u64(flags, "batch", 1) as usize,
                t: flag_u64(flags, "seq", 16).max(1) as usize,
                gates: match flags.get("kind").map(String::as_str) {
                    Some("gru") => 3,
                    _ => 4,
                },
            },
            StackSpec {
                layers: flag_u64(flags, "layers", 1).max(1) as usize,
                bidirectional: flags.contains_key("bi"),
                proj: flag_u64(flags, "proj", 0) as usize,
            },
        ))
    }
}

/// The stacked variant of `sharp plan`: one chosen plan per layer
/// (scored against THAT layer's input width — what the stack executable
/// binds), plus the sim's fill/drain pipeline estimate for the depth.
fn print_stack_plan(
    dims: &ModelDims,
    spec: &StackSpec,
    mode: &PlanMode,
    isa: Isa,
    dtype: Dtype,
    json: bool,
) -> Result<()> {
    let mut layer_rows = Vec::new();
    for l in 0..spec.layers {
        let d_l = spec.layer_input_dim(l, dims.d, dims.h);
        let ldims = ModelDims { d: d_l, ..*dims };
        let plan = tuner::plan_for_dtype(&ldims, mode, isa, dtype);
        let score = cost::score(&plan, &ldims);
        layer_rows.push((l, d_l, plan, score));
    }
    let est = stack_pipeline_estimate(
        &stack_step_flops(dims.d, dims.h, dims.b, dims.gates, spec.proj, spec.layers),
        dims.t,
    );
    // Bidirectional stacks run the sequential driver (the reverse
    // direction consumes reversed time, so steps cannot hand off).
    let pipelines = spec.layers > 1 && !spec.bidirectional;
    if json {
        let mut root = BTreeMap::new();
        // v2: adds the weight dtype (plan rows render mr/nr/sched@isa/dtype).
        root.insert("schema".into(), Json::Str("sharp-plan-stack/v2".into()));
        root.insert("dtype".into(), Json::Str(dtype.name().into()));
        for (key, v) in [
            ("d", dims.d),
            ("h", dims.h),
            ("b", dims.b),
            ("t", dims.t),
            ("gates", dims.gates),
            ("layers", spec.layers),
            ("proj", spec.proj),
        ] {
            root.insert(key.into(), Json::Num(v as f64));
        }
        root.insert("bidirectional".into(), Json::Bool(spec.bidirectional));
        root.insert("pipelines".into(), Json::Bool(pipelines));
        if pipelines {
            root.insert("predicted_speedup".into(), Json::Num(est.speedup));
        }
        let rows = layer_rows
            .iter()
            .map(|(l, d_l, plan, score)| {
                let mut o = BTreeMap::new();
                o.insert("layer".into(), Json::Num(*l as f64));
                o.insert("d".into(), Json::Num(*d_l as f64));
                o.insert("plan".into(), Json::Str(plan.describe()));
                o.insert("cost".into(), Json::Num(score.cost));
                o.insert("utilization".into(), Json::Num(score.utilization));
                Json::Obj(o)
            })
            .collect();
        root.insert("layer_plans".into(), Json::Arr(rows));
        println!("{}", json::write(&Json::Obj(root)));
    } else {
        let mut table = Table::new(&format!(
            "per-layer execution plans: L={}{}{} D={} H={} B={} T={} gates={} (mode {}, isa {}, dtype {})",
            spec.layers,
            if spec.bidirectional { " bidirectional" } else { "" },
            if spec.proj > 0 {
                format!(" P={}", spec.proj)
            } else {
                String::new()
            },
            dims.d,
            dims.h,
            dims.b,
            dims.t,
            dims.gates,
            mode.name(),
            isa.name(),
            dtype.name()
        ))
        .header(&["layer", "d_in", "plan", "cost", "util%"]);
        for (l, d_l, plan, score) in &layer_rows {
            table.row(&[
                format!("layer{l}"),
                format!("{d_l}"),
                plan.describe(),
                format!("{:.0}", score.cost),
                format!("{:.1}", score.utilization * 100.0),
            ]);
        }
        println!("{}", table.render());
        if pipelines {
            println!(
                "stack pipeline: {} layer workers, predicted {:.2}x over sequential \
                 (fill/drain ideal {:.2}x at T={})",
                spec.layers,
                est.speedup,
                (spec.layers * dims.t) as f64 / (dims.t + spec.layers - 1) as f64,
                dims.t
            );
        } else if spec.bidirectional {
            println!("stack pipeline: unavailable (bidirectional runs the sequential driver)");
        }
    }
    Ok(())
}

/// `sharp plan`: print the planner's candidate table and choice for one
/// model shape — the runtime twin of `sharp explore` (which does the
/// same for the simulated accelerator's K). No artifacts needed unless
/// `--artifact` names one.
fn cmd_plan(flags: &HashMap<String, String>) -> i32 {
    let run = || -> Result<()> {
        let rt = parse_runtime(flags)?;
        let mode = rt.plan;
        let (dims, spec) = plan_dims(flags)?;
        // The dispatch the kernels would actually run here: --kernel /
        // SHARP_FORCE_KERNEL pin, else the best detected ISA.
        let isa = rt.resolve_isa()?;
        if spec.is_stacked() {
            return print_stack_plan(&dims, &spec, &mode, isa, rt.dtype, flags.contains_key("json"));
        }
        let forced = rt.force_kernel.is_some()
            || sharp::runtime::kernel::simd::forced_from_env()?.is_some();
        let mut cands = tuner::enumerate_dtype(&dims, isa, rt.dtype);
        let chosen = tuner::plan_for_dtype(&dims, &mode, isa, rt.dtype);
        // A pinned geometry outside the tuner grid still gets a scored
        // row, so exactly one candidate always carries the chosen mark.
        if !cands.iter().any(|c| c.plan == chosen) {
            cands.push(tuner::Candidate {
                plan: chosen,
                score: cost::score(&chosen, &dims),
            });
        }
        if flags.contains_key("json") {
            let mut dims_j = BTreeMap::new();
            for (key, v) in [
                ("d", dims.d),
                ("h", dims.h),
                ("b", dims.b),
                ("t", dims.t),
                ("gates", dims.gates),
            ] {
                dims_j.insert(key.into(), Json::Num(v as f64));
            }
            let mut chosen_j = BTreeMap::new();
            chosen_j.insert("mr".into(), Json::Num(chosen.geometry.mr as f64));
            chosen_j.insert("nr".into(), Json::Num(chosen.geometry.nr as f64));
            chosen_j.insert("schedule".into(), Json::Str(chosen.schedule.name().into()));
            chosen_j.insert("isa".into(), Json::Str(chosen.geometry.isa.name().into()));
            chosen_j.insert("dtype".into(), Json::Str(chosen.geometry.dtype.name().into()));
            chosen_j.insert(
                "vector_width".into(),
                Json::Num(chosen.geometry.isa.lanes() as f64),
            );
            chosen_j.insert(
                "min_flops_per_thread".into(),
                Json::Num(chosen.geometry.min_flops_per_thread as f64),
            );
            let mut isa_j = BTreeMap::new();
            isa_j.insert("name".into(), Json::Str(isa.name().into()));
            isa_j.insert("lanes".into(), Json::Num(isa.lanes() as f64));
            isa_j.insert("detected".into(), Json::Str(Isa::detect().name().into()));
            isa_j.insert("forced".into(), Json::Bool(forced));
            let rows = cands
                .iter()
                .map(|c| {
                    let mut o = BTreeMap::new();
                    o.insert("mr".into(), Json::Num(c.plan.geometry.mr as f64));
                    o.insert("nr".into(), Json::Num(c.plan.geometry.nr as f64));
                    o.insert("schedule".into(), Json::Str(c.plan.schedule.name().into()));
                    o.insert("cost".into(), Json::Num(c.score.cost));
                    o.insert("utilization".into(), Json::Num(c.score.utilization));
                    o.insert("scratch_f32".into(), Json::Num(c.score.scratch_f32 as f64));
                    o.insert("chosen".into(), Json::Bool(c.plan == chosen));
                    Json::Obj(o)
                })
                .collect();
            let mut root = BTreeMap::new();
            // v3: adds the weight dtype (top-level + chosen.dtype) so
            // downstream parsers see ISA and dtype side by side.
            root.insert("schema".into(), Json::Str("sharp-plan/v3".into()));
            root.insert("dims".into(), Json::Obj(dims_j));
            root.insert("dtype".into(), Json::Str(rt.dtype.name().into()));
            root.insert("mode".into(), Json::Str(mode.name().into()));
            root.insert("isa".into(), Json::Obj(isa_j));
            root.insert("chosen".into(), Json::Obj(chosen_j));
            root.insert("candidates".into(), Json::Arr(rows));
            println!("{}", json::write(&Json::Obj(root)));
        } else {
            let mut table = Table::new(&format!(
                "execution plan candidates: D={} H={} B={} T={} gates={} (mode {}, isa {}, dtype {})",
                dims.d,
                dims.h,
                dims.b,
                dims.t,
                dims.gates,
                mode.name(),
                isa.name(),
                rt.dtype.name()
            ))
            .header(&["rank", "mr", "nr", "schedule", "cost", "util%", "scratch KiB", ""]);
            for (i, c) in cands.iter().enumerate() {
                table.row(&[
                    format!("{}", i + 1),
                    format!("{}", c.plan.geometry.mr),
                    format!("{}", c.plan.geometry.nr),
                    c.plan.schedule.name().to_string(),
                    format!("{:.0}", c.score.cost),
                    format!("{:.1}", c.score.utilization * 100.0),
                    format!("{:.1}", c.score.scratch_f32 as f64 * 4.0 / 1024.0),
                    if c.plan == chosen { "<= chosen".into() } else { String::new() },
                ]);
            }
            println!("{}", table.render());
            println!(
                "chosen plan: {} (thread gate {} FLOPs/thread)",
                chosen.describe(),
                chosen.geometry.min_flops_per_thread
            );
            println!(
                "kernel isa: {} ({} f32 lane{}, {})",
                isa.name(),
                isa.lanes(),
                if isa.lanes() == 1 { "" } else { "s" },
                if forced {
                    "forced".to_string()
                } else {
                    format!("detected: {}", Isa::detect().name())
                }
            );
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("plan failed: {e:#}");
            2
        }
    }
}

/// Build the worker pool from the shared `serve` flags — both serve
/// modes (local trace replay and the TCP listener) go through this, so
/// pool behavior cannot diverge between them.
fn start_pool(flags: &HashMap<String, String>, hidden: &[usize]) -> Result<Server> {
    let overload = match flags.get("overload").map(String::as_str) {
        None | Some("block") => OverloadPolicy::Block,
        Some("shed") => OverloadPolicy::Shed,
        Some(other) => return Err(anyhow!("--overload must be block or shed, got {other:?}")),
    };
    let faults = match flags.get("faults") {
        Some(spec) => Some(FaultPlan::parse(spec)?),
        None => None, // Server::start falls back to SHARP_FAULTS
    };
    Server::start(ServerConfig {
        hidden: hidden.to_vec(),
        workers: flag_u64(flags, "workers", 1) as usize,
        accel_macs: flag_u64(flags, "macs", 4096),
        max_fused_lanes: flag_u64(flags, "fused-lanes", 64).max(1) as usize,
        runtime: parse_runtime(flags)?,
        overload,
        watchdog: std::time::Duration::from_millis(flag_u64(flags, "watchdog", 2000).max(1)),
        faults,
        ..Default::default()
    })
}

/// `serve --listen`: expose the pool over TCP and block until a drain
/// (control-plane `{"cmd":"drain"}` or `sharp drain`) tears it down.
fn run_listen(flags: &HashMap<String, String>, addr: &str, hidden: &[usize]) -> Result<()> {
    ensure!(
        !addr.is_empty(),
        "--listen needs an address (host:port; port 0 picks an ephemeral one)"
    );
    let server = start_pool(flags, hidden)?;
    // The same --faults spec arms both layers: worker faults fire in the
    // pool, conn faults in the framing layer.
    let net_faults = match flags.get("faults") {
        Some(spec) => Some(FaultPlan::parse(spec)?),
        None => None, // Listener::start falls back to SHARP_FAULTS
    };
    let listener = Listener::start(
        server,
        NetConfig {
            addr: addr.to_string(),
            max_conns: flag_u64(flags, "max-conns", 64).max(1) as usize,
            read_timeout: std::time::Duration::from_millis(
                flag_u64(flags, "read-timeout", 2000).max(1),
            ),
            idle_timeout: std::time::Duration::from_millis(
                flag_u64(flags, "idle-timeout", 60_000).max(1),
            ),
            drain_linger: std::time::Duration::from_millis(flag_u64(flags, "drain-linger", 500)),
            faults: net_faults,
            ..Default::default()
        },
    )?;
    // Scripts (and the e2e suite) parse this line for the bound port.
    println!("listening on {}", listener.local_addr());
    println!("drain via: sharp drain --addr {}", listener.local_addr());
    let summary = listener.wait()?;
    println!(
        "drained: {} streaming sessions fenced, {} connections drained",
        summary.fenced, summary.conns_drained
    );
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> i32 {
    let n = flag_u64(flags, "requests", 64) as usize;
    let rate = flag_u64(flags, "rate", 200) as f64;
    let workers = flag_u64(flags, "workers", 1) as usize;
    let hidden = flag_usize_list(flags, "hidden", "256");
    let streaming = flags.contains_key("streaming");
    let run = || -> Result<()> {
        ensure!(!hidden.is_empty(), "--hidden needs at least one dim");
        if let Some(addr) = flags.get("listen") {
            return run_listen(flags, addr, &hidden);
        }
        // Peek at the manifest for per-dim bucket seq-lens (cheap; each
        // worker replica owns its own executable state).
        let store = ArtifactStore::open_default()?;
        let mut dim_lens: Vec<(usize, Vec<u64>)> = Vec::new();
        for &h in &hidden {
            let lens: Vec<u64> = store.manifest.seq_entries(h).map(|e| e.t as u64).collect();
            ensure!(!lens.is_empty(), "no seq artifacts for H={h}");
            dim_lens.push((h, lens));
        }
        drop(store);
        let deadline = match flags.get("deadline") {
            Some(v) => Some(std::time::Duration::from_millis(v.parse::<u64>().map_err(
                |_| anyhow!("--deadline needs a budget in milliseconds, got {v:?}"),
            )?)),
            None => None,
        };
        let server = start_pool(flags, &hidden)?;
        // One trace per served dim (the payload width must match the
        // variant), merged into one timeline by arrival.
        let share = (n / dim_lens.len()).max(1);
        let mut trace: Vec<(usize, sharp::workloads::Request)> = Vec::new();
        for (i, (h, lens)) in dim_lens.iter().enumerate() {
            let t = TraceConfig {
                kind: TraceKind::Poisson,
                n_requests: share,
                rate_rps: rate / dim_lens.len() as f64,
                seq_lens: lens.clone(),
                input_dim: *h as u64,
                seed: flag_u64(flags, "seed", 7) + i as u64,
            }
            .generate();
            trace.extend(t.into_iter().map(|r| (*h, r)));
        }
        trace.sort_by(|a, b| a.1.arrival_s.total_cmp(&b.1.arrival_s));
        let total = trace.len();
        println!(
            "replaying {total} requests at ~{rate} rps (H={hidden:?}, {workers} worker{}{})...",
            if workers == 1 { "" } else { "s" },
            if streaming { ", streaming sessions" } else { "" }
        );
        let t0 = std::time::Instant::now();
        // Per pending reply: (session, frames) for streaming chunks so
        // the load generator can attribute latency and steps.
        let mut pending: Vec<(Option<(u64, usize)>, _)> = Vec::new();
        let mut sids: Vec<u64> = Vec::new();
        for (di, (h, r)) in trace.into_iter().enumerate() {
            let dt = r.arrival_s - t0.elapsed().as_secs_f64();
            if dt > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(dt));
            }
            if streaming {
                // Each trace request becomes one streaming session: its
                // frames go in as chunks, the (h, c) carry persists on
                // the session's owner worker, and per-session FIFO
                // ordering keeps the carry sequential. Concurrent
                // sessions' chunks fuse into batched steps on the
                // worker (DESIGN.md §9).
                let sid = di as u64; // unique across the merged traces
                server.begin_session(sid, h)?;
                sids.push(sid);
                let frames = r.seq_len as usize;
                let chunk = 4usize.min(frames).max(1);
                let mut off = 0usize;
                while off < frames {
                    let len = chunk.min(frames - off);
                    let payload = r.payload[off * h..(off + len) * h].to_vec();
                    let mut req = InferenceRequest::new(r.id, len, payload)
                        .with_session(sid)
                        .with_hidden(h);
                    if let Some(d) = deadline {
                        req = req.with_deadline(d);
                    }
                    pending.push((Some((sid, len)), server.submit(req)));
                    off += len;
                }
            } else {
                let mut req =
                    InferenceRequest::new(r.id, r.seq_len as usize, r.payload).with_hidden(h);
                if let Some(d) = deadline {
                    req = req.with_deadline(d);
                }
                pending.push((None, server.submit(req)));
            }
        }
        let issued = pending.len();
        let mut ok = 0;
        // Streaming load-gen stats: pooled per-session chunk latencies
        // plus frames served, for the p50/p99 + steps/s report.
        let mut chunk_lat = sharp::util::stats::Samples::new();
        let mut frames_ok = 0usize;
        for (meta, rx) in pending {
            if let Ok(resp) = rx.recv()? {
                ok += 1;
                if let Some((_sid, len)) = meta {
                    chunk_lat.push(resp.latency_s);
                    frames_ok += len;
                }
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let mut stream_json: Option<Json> = None;
        if streaming {
            let closed = sids
                .iter()
                .filter(|s| server.end_session(**s).ok().flatten().is_some())
                .count();
            println!(
                "{ok}/{issued} chunks succeeded; {closed}/{} sessions carried state to the end",
                sids.len()
            );
            let steps_per_s = frames_ok as f64 / wall_s.max(1e-9);
            println!(
                "streaming {} sessions, {issued} chunks, {frames_ok} frames in {:.2}s\n\
                 chunk latency p50={:.2}ms p99={:.2}ms | aggregate {:.0} steps/s",
                sids.len(),
                wall_s,
                chunk_lat.p50() * 1e3,
                chunk_lat.p99() * 1e3,
                steps_per_s
            );
            let mut sj = BTreeMap::new();
            sj.insert("sessions".into(), Json::Num(sids.len() as f64));
            sj.insert("chunks".into(), Json::Num(issued as f64));
            sj.insert("frames".into(), Json::Num(frames_ok as f64));
            sj.insert("wall_s".into(), Json::Num(wall_s));
            sj.insert("chunk_latency_p50_s".into(), Json::Num(chunk_lat.p50()));
            sj.insert("chunk_latency_p99_s".into(), Json::Num(chunk_lat.p99()));
            sj.insert("steps_per_s".into(), Json::Num(steps_per_s));
            stream_json = Some(Json::Obj(sj));
        } else {
            println!("{ok}/{issued} succeeded");
        }
        let mut metrics = server.metrics()?;
        println!("{}", metrics.render());
        if let Some(path) = flags.get("json") {
            ensure!(!path.is_empty(), "--json needs a file argument");
            let mut root = match metrics.snapshot_json() {
                Json::Obj(o) => o,
                _ => unreachable!("metrics snapshot is an object"),
            };
            if let Some(sj) = stream_json {
                root.insert("load_gen".into(), sj);
            }
            std::fs::write(path, json::write(&Json::Obj(root)))
                .map_err(|e| anyhow!("write {path}: {e}"))?;
            println!("metrics snapshot written to {path}");
        }
        server.shutdown();
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            1
        }
    }
}

/// Per-connection loadgen outcome, merged across threads at the end.
#[derive(Default)]
struct LoadTally {
    ok: usize,
    failed: usize,
    /// Extra tries beyond the first, summed over successful requests.
    retries: u64,
    /// Times the client transport re-dialed.
    reconnects: u64,
    /// Streaming only: observed `session_steps` resets (carry lost).
    lost_carries: u64,
    lat_s: Vec<f64>,
}

#[allow(clippy::too_many_arguments)]
fn loadgen_conn(
    addr: &str,
    conn_idx: usize,
    n: usize,
    hidden: u32,
    seq: u32,
    seed: u64,
    streaming: bool,
    policy: &RetryPolicy,
    io_timeout: std::time::Duration,
) -> Result<LoadTally> {
    let mut client = NetClient::connect(addr.to_string(), io_timeout)?;
    client.seed_jitter(seed ^ (conn_idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut rng = Rng::new(seed.wrapping_add(conn_idx as u64) + 1);
    let mut t = LoadTally::default();
    let sid = 0x4C47_0000_0000_0000u64 | conn_idx as u64; // "LG"-prefixed ids
    if streaming {
        match client.begin(sid, hidden)? {
            Ok(()) => {}
            Err(e) => bail!("session begin refused: {e}"),
        }
    }
    let mut last_steps = 0u64;
    for j in 0..n {
        let id = ((conn_idx as u64) << 32) | j as u64;
        let mut req = NetRequest::new(
            id,
            seq,
            rng.vec_f32(seq as usize * hidden as usize, -1.0, 1.0),
        );
        req.hidden = Some(hidden);
        if streaming {
            req.session = Some(sid);
        }
        let t1 = std::time::Instant::now();
        match client.infer_retry(&req, policy) {
            Ok((resp, tries)) => {
                t.ok += 1;
                t.retries += u64::from(tries.saturating_sub(1));
                t.lat_s.push(t1.elapsed().as_secs_f64());
                if streaming {
                    if let Some(steps) = resp.session_steps {
                        // A step count at or below the last one means the
                        // carry restarted server-side (LRU eviction or a
                        // worker respawn) — loud, never silent.
                        if steps <= last_steps {
                            t.lost_carries += 1;
                        }
                        last_steps = steps;
                    }
                }
            }
            Err(e) => {
                t.failed += 1;
                if t.failed == 1 {
                    eprintln!("conn {conn_idx}: {e:#}");
                }
            }
        }
    }
    if streaming {
        let _ = client.end(sid);
    }
    t.reconnects = client.reconnects;
    Ok(t)
}

fn cmd_loadgen(flags: &HashMap<String, String>) -> i32 {
    let run = || -> Result<()> {
        let addr = flags
            .get("addr")
            .filter(|a| !a.is_empty())
            .ok_or_else(|| anyhow!("loadgen needs --addr HOST:PORT (see `serve --listen`)"))?;
        let total = flag_u64(flags, "requests", 64).max(1) as usize;
        let conns = (flag_u64(flags, "conns", 1).max(1) as usize).min(total);
        let hidden = flag_u64(flags, "hidden", 256) as u32;
        let seq = flag_u64(flags, "seq", 16).max(1) as u32;
        let seed = flag_u64(flags, "seed", 7);
        let streaming = flags.contains_key("streaming");
        let policy = RetryPolicy {
            max_attempts: flag_u64(flags, "retries", 6).max(1) as u32,
            base: std::time::Duration::from_millis(flag_u64(flags, "backoff-ms", 10).max(1)),
            cap: std::time::Duration::from_millis(flag_u64(flags, "backoff-cap-ms", 500).max(1)),
            seed,
        };
        let io_timeout =
            std::time::Duration::from_millis(flag_u64(flags, "io-timeout", 5000).max(1));
        println!(
            "loadgen: {total} requests over {conns} connection{} to {addr} (H={hidden}, T={seq}{})",
            if conns == 1 { "" } else { "s" },
            if streaming { ", streaming" } else { "" }
        );
        let t0 = std::time::Instant::now();
        let outcomes: Vec<Result<LoadTally>> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for c in 0..conns {
                // Even split; the first (total % conns) connections take
                // one extra request.
                let n = total / conns + usize::from(c < total % conns);
                let policy = &policy;
                handles.push(scope.spawn(move || {
                    loadgen_conn(addr, c, n, hidden, seq, seed, streaming, policy, io_timeout)
                }));
            }
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("loadgen thread panicked")))
                })
                .collect()
        });
        let wall_s = t0.elapsed().as_secs_f64();
        let mut sum = LoadTally::default();
        let mut lat = Samples::new();
        for o in outcomes {
            let t = o?;
            sum.ok += t.ok;
            sum.failed += t.failed;
            sum.retries += t.retries;
            sum.reconnects += t.reconnects;
            sum.lost_carries += t.lost_carries;
            for v in t.lat_s {
                lat.push(v);
            }
        }
        println!(
            "{}/{total} ok, {} failed | retries={} reconnects={} lost_carries={}",
            sum.ok, sum.failed, sum.retries, sum.reconnects, sum.lost_carries
        );
        if !lat.is_empty() {
            println!(
                "latency p50={:.2}ms p99={:.2}ms | {:.0} req/s over {:.2}s",
                lat.p50() * 1e3,
                lat.p99() * 1e3,
                sum.ok as f64 / wall_s.max(1e-9),
                wall_s
            );
        }
        if let Some(path) = flags.get("json") {
            ensure!(!path.is_empty(), "--json needs a file argument");
            let mut root = BTreeMap::new();
            root.insert("schema".into(), Json::Str("sharp-loadgen/v1".into()));
            root.insert("requests".into(), Json::Num(total as f64));
            root.insert("conns".into(), Json::Num(conns as f64));
            root.insert("ok".into(), Json::Num(sum.ok as f64));
            root.insert("failed".into(), Json::Num(sum.failed as f64));
            root.insert("retries".into(), Json::Num(sum.retries as f64));
            root.insert("reconnects".into(), Json::Num(sum.reconnects as f64));
            root.insert("lost_carries".into(), Json::Num(sum.lost_carries as f64));
            root.insert("wall_s".into(), Json::Num(wall_s));
            root.insert("latency_p50_s".into(), Json::Num(lat.p50()));
            root.insert("latency_p99_s".into(), Json::Num(lat.p99()));
            std::fs::write(path, json::write(&Json::Obj(root)))
                .map_err(|e| anyhow!("write {path}: {e}"))?;
            println!("loadgen summary written to {path}");
        }
        ensure!(
            sum.ok > 0,
            "no request succeeded ({} failed) — is the server draining or down?",
            sum.failed
        );
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("loadgen failed: {e:#}");
            1
        }
    }
}

fn cmd_drain(flags: &HashMap<String, String>) -> i32 {
    let run = || -> Result<()> {
        let addr = flags
            .get("addr")
            .filter(|a| !a.is_empty())
            .ok_or_else(|| anyhow!("drain needs --addr HOST:PORT (see `serve --listen`)"))?;
        let cmd = match flags.get("cmd").map(String::as_str) {
            None | Some("drain") => "drain",
            Some("health") => "health",
            Some("metrics") => "metrics",
            Some(other) => bail!("--cmd must be drain, health, or metrics, got {other:?}"),
        };
        let io_timeout =
            std::time::Duration::from_millis(flag_u64(flags, "io-timeout", 5000).max(1));
        let mut client = NetClient::connect(addr.to_string(), io_timeout)?;
        let reply = client.control(&format!("{{\"cmd\":\"{cmd}\"}}"))?;
        println!("{reply}");
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("drain failed: {e:#}");
            1
        }
    }
}

fn usage() -> i32 {
    eprintln!(
        "usage: sharp <command>\n\
         commands:\n\
           list            list exhibit ids: {:?}\n\
           figure <id>     render one exhibit\n\
           table <id>      render one table exhibit\n\
           all             every exhibit (--json <dir> for files)\n\
           simulate        --macs N --hidden H --seq T --k K --sched S\n\
           explore         --macs N --hidden H --seq T\n\
           infer <name>    run an artifact against its goldens\n\
                           (--threads T, --plan auto|calibrated|fixed[:MRxNR],\n\
                           --kernel scalar|avx2|neon, --quant f32|int8:\n\
                           quantized weights + measured GFLOP/s next to\n\
                           the energy model's estimated GFLOPS/W)\n\
           serve           --requests N --rate R --workers W\n\
                           --hidden H[,H2,...] --streaming --threads T\n\
                           --fused-lanes L --json FILE --quant f32|int8\n\
                           --plan auto|calibrated|fixed[:MRxNR]\n\
                           --deadline MS (per-request budget; late =>\n\
                           typed DeadlineExceeded, never a hang)\n\
                           --overload block|shed --watchdog MS\n\
                           --faults SPEC (e.g. panic@worker1:req17,\n\
                           stall@worker0:40ms:req5; or SHARP_FAULTS)\n\
                           --listen ADDR serves the pool over TCP\n\
                           (host:port; port 0 = ephemeral, printed as\n\
                           \"listening on ...\"); --max-conns N\n\
                           --read-timeout MS --idle-timeout MS\n\
                           --drain-linger MS; net chaos via --faults\n\
                           disconnect@connC:frameF, garble@connC:frameF,\n\
                           stall@connC:DDms[:frameF]\n\
           loadgen         --addr HOST:PORT --requests N --conns C\n\
                           --hidden H --seq T --streaming --seed S\n\
                           --retries K --backoff-ms B --backoff-cap-ms M\n\
                           --json FILE (capped exponential backoff with\n\
                           jitter on retryable verdicts; reconnects and\n\
                           resumes sessions on dropped connections)\n\
           drain           --addr HOST:PORT [--cmd drain|health|metrics]\n\
                           control plane: graceful drain = stop accepting,\n\
                           fence streaming sessions, flush replies, refuse\n\
                           new work with a typed retryable error\n\
           plan            --hidden H [--d D --batch B --seq T --kind lstm|gru\n\
                           --layers L --bi --proj P] | --artifact NAME;\n\
                           --plan MODE --kernel ISA --quant DTYPE --json\n\
                           (stacked shapes print one plan row per layer\n\
                           + pipeline estimate)\n\
           artifacts       list AOT artifacts\n\
         env: SHARP_FORCE_KERNEL=scalar|avx2|neon pins the GEMM micro-kernel\n\
         ISA process-wide (unavailable => loud error; default: detect)",
        experiments::ALL_IDS
    );
    2
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flags = parse_flags(&args[1.min(args.len())..]);
    let code = match args.first().map(String::as_str) {
        Some("list") => cmd_list(),
        Some("figure") | Some("table") => match args.get(1) {
            Some(id) => cmd_exhibit(id),
            None => usage(),
        },
        Some("all") => cmd_all(&flags),
        Some("simulate") => cmd_simulate(&flags),
        Some("explore") => cmd_explore(&flags),
        Some("infer") => match args.get(1) {
            Some(name) => cmd_infer(name, &flags),
            None => usage(),
        },
        Some("serve") => cmd_serve(&flags),
        Some("loadgen") => cmd_loadgen(&flags),
        Some("drain") => cmd_drain(&flags),
        Some("plan") => cmd_plan(&flags),
        Some("artifacts") => cmd_artifacts(),
        _ => usage(),
    };
    std::process::exit(code);
}
