//! Energy/power/area report for a chosen design point and workload — the
//! Table 2 / Fig. 15 machinery applied to user input, including the
//! paper's headline efficiency metric (GFLOPS/W; the paper reports
//! 321 GFLOPS/W at the 64K design's 0.32 TFLOPS/W).
//!
//! Run: `cargo run --release --example energy_report [macs] [hidden]`

use sharp::config::LstmConfig;
use sharp::energy::{area_breakdown, power_report};
use sharp::experiments::common::k_opt_config;
use sharp::sched::ScheduleKind;
use sharp::sim::simulate;
use sharp::util::table::{fnum, fpct, Table};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let macs: u64 = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(65536);
    let hidden: u64 = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(1024);

    let model = LstmConfig::square(hidden);
    let cfg = k_opt_config(macs, &model);
    let sim = simulate(&cfg, &model, ScheduleKind::Unfolded);
    let power = power_report(&cfg, &sim);
    let area = area_breakdown(&cfg);

    println!(
        "design: {} MACs @ {:.0} MHz, K={} x {} row-groups | workload h={hidden} T={}",
        macs,
        cfg.freq_hz / 1e6,
        cfg.mapping.k,
        cfg.mapping.row_groups,
        model.seq_len
    );
    println!(
        "latency {:.2} us | utilization {} | achieved {:.2} TFLOPS\n",
        sim.time_s() * 1e6,
        fpct(sim.utilization()),
        sim.achieved_flops() / 1e12
    );

    let mut pt = Table::new("power").header(&["component", "watts", "share"]);
    let shares = power.shares();
    for (i, (name, w)) in [
        ("compute-unit", power.compute_w),
        ("SRAM buffers", power.sram_w),
        ("main memory", power.dram_w),
        ("activation", power.activation_w),
        ("controller", power.controller_w),
    ]
    .iter()
    .enumerate()
    {
        pt.row(&[name.to_string(), fnum(*w), fpct(shares[i])]);
    }
    pt.row(&["TOTAL".to_string(), fnum(power.total_w()), "100%".to_string()]);
    println!("{}", pt.render());

    let mut at = Table::new("area (32 nm)").header(&["component", "mm^2"]);
    at.row(&["compute-unit", &fnum(area.compute_mm2)]);
    at.row(&["SRAM buffers", &fnum(area.sram_mm2)]);
    at.row(&["MFUs", &fnum(area.mfu_mm2)]);
    at.row(&["add-reduce/mux", &fnum(area.interconnect_mm2)]);
    at.row(&["controller", &fnum(area.controller_mm2)]);
    at.row(&["TOTAL", &fnum(area.total_mm2())]);
    println!("{}", at.render());

    println!(
        "efficiency: {:.0} GFLOPS/W (paper headline: 321 GFLOPS/W at the 64K design)",
        power.flops_per_watt(sim.achieved_flops()) / 1e9
    );
    println!(
        "energy for this inference: {:.2} uJ",
        power.energy_j() * 1e6
    );
}
