//! Quickstart: load an AOT LSTM artifact, run one sequence through the
//! built-in executor, verify against the golden output, and print what the
//! SHARP cycle model says the modeled ASIC would have taken.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use sharp::error::{ensure, Result};

use sharp::config::LstmConfig;
use sharp::experiments::common::sharp_tuned;
use sharp::runtime::{literal::max_abs_diff, ArtifactStore, LstmExecutable};

fn main() -> Result<()> {
    // 1. Open the artifact store (built once by `make artifacts`; python
    //    is never needed again after that).
    let store = ArtifactStore::open_default()?;
    let name = "seq_h64_t8_b1";
    println!("loading artifact '{name}' from {:?}", store.dir);

    // 2. Bind the compiled executable to its shipped parameter set.
    let exe = LstmExecutable::from_store_goldens(&store, name)?;
    let e = exe.entry.clone();
    println!("model: T={} B={} D={} H={} (gate order {})", e.t, e.b, e.d, e.h, store.manifest.gate_order);

    // 3. Run the golden inputs through the built-in dense executor.
    let golden_in = |n: &str| store.golden(e.inputs.iter().find(|i| i.name == n).unwrap());
    let out = exe.run(&golden_in("xs")?, &golden_in("h0")?, &golden_in("c0")?)?;

    // 4. Check the numerics against the AOT-time goldens (which were
    //    themselves checked against the pure-jnp oracle).
    let golden_h = store.golden(&e.outputs[1])?;
    let diff = max_abs_diff(&out.h_t, &golden_h);
    println!("max |h_t - golden| = {diff:.3e}");
    ensure!(diff < 1e-4, "numerics mismatch");

    // 5. Ask the cycle simulator what the SHARP ASIC would take for this
    //    workload at the paper's four budgets.
    println!("\nSHARP cycle-model estimates for this workload:");
    let model = LstmConfig::square(e.h as u64).with_seq_len(e.t as u64);
    for macs in sharp::config::presets::MAC_BUDGETS {
        let r = sharp_tuned(macs, &model);
        println!(
            "  {:>4} MACs: {:>7} cycles = {:>8.2} us  (utilization {:>5.1}%)",
            sharp::config::presets::budget_label(macs),
            r.cycles,
            r.time_s() * 1e6,
            r.utilization() * 100.0
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
