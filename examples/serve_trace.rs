//! End-to-end serving driver (the repo's E2E validation workload, see
//! EXPERIMENTS.md §E2E): start the coordinator, replay a synthetic
//! ASR-like request trace (variable-length sequences, Poisson arrivals)
//! through the dynamic batcher onto compiled artifacts, and report
//! latency percentiles, throughput, and the SHARP accelerator-time
//! estimate per request.
//!
//! Run: `make artifacts && cargo run --release --example serve_trace [n] [rate] [workers]`

use sharp::error::{ensure, Result};

use sharp::coordinator::{InferenceRequest, Server, ServerConfig};
use sharp::runtime::ArtifactStore;
use sharp::workloads::{TraceConfig, TraceKind};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(96);
    let rate: f64 = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(40.0);
    let workers: usize = args.get(3).and_then(|v| v.parse().ok()).unwrap_or(2);
    let hidden = 256usize;

    // Bucket inventory comes from the manifest (each worker replica owns
    // its own executable state).
    let store = ArtifactStore::open_default()?;
    let seq_lens: Vec<u64> = store
        .manifest
        .seq_entries(hidden)
        .map(|e| e.t as u64)
        .collect();
    drop(store);
    ensure!(!seq_lens.is_empty(), "run `make artifacts` first");

    let server = Server::start(ServerConfig {
        hidden: vec![hidden],
        workers,
        accel_macs: 4096,
        ..Default::default()
    })?;

    // ASR-like trace: utterance chunks of 8-32 frames, Poisson arrivals.
    let trace = TraceConfig {
        kind: TraceKind::Poisson,
        n_requests: n,
        rate_rps: rate,
        seq_lens,
        input_dim: hidden as u64,
        seed: 20260710,
    }
    .generate();

    println!("serve_trace: {n} requests, ~{rate} rps, H={hidden}, {workers} workers");
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for r in &trace {
        let wait = r.arrival_s - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(wait));
        }
        pending.push(server.submit(InferenceRequest::new(
            r.id,
            r.seq_len as usize,
            r.payload.clone(),
        )));
    }
    let mut ok = 0usize;
    let mut accel_total = 0.0f64;
    for rx in pending {
        match rx.recv()? {
            Ok(resp) => {
                ok += 1;
                accel_total += resp.accel_time_s;
            }
            Err(e) => eprintln!("request failed: {e}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\n== E2E serving report ==");
    println!("{ok}/{n} requests served in {wall:.2}s");
    println!("{}", server.metrics()?.render());
    println!(
        "modeled SHARP@4K total accel time: {:.1} us ({}x faster than this CPU run)",
        accel_total * 1e6,
        (wall / accel_total.max(1e-12)) as u64
    );
    server.shutdown();
    ensure!(ok == n, "not all requests served");
    println!("serve_trace OK");
    Ok(())
}
