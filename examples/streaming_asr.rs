//! Streaming inference: the online-ASR pattern the paper's intro
//! motivates — utterance frames arrive in chunks, and the recurrent
//! (h, c) state must persist across chunks. Drives the serving pool's
//! streaming sessions (begin/chunk/end): chunks route to the session's
//! owner worker (affinity keeps the carry on one thread), execute with
//! the carried state via `run_prefix`, and the example proves the
//! chunked result is bit-identical to running the whole utterance in one
//! shot on the same artifact (the schedule-invariance argument of the
//! Unfolded decomposition).
//!
//! Run: `make artifacts && cargo run --release --example streaming_asr`

use sharp::error::{anyhow, ensure, Result};

use sharp::coordinator::{Server, ServerConfig};
use sharp::runtime::{
    literal::{assert_bits_eq, max_abs_diff},
    ArtifactStore, LstmExecutable,
};
use sharp::util::rng::Rng;

fn main() -> Result<()> {
    let hidden = 256usize;
    // A 16-frame utterance, streamed in chunks of 3/5/8 frames.
    let t = 16usize;
    let mut rng = Rng::new(42);
    let utterance = rng.vec_f32(t * hidden, -1.0, 1.0);
    let chunks = [3usize, 5, 8];
    let session = 7u64;

    // Multi-worker pool: session affinity pins the carry to one worker.
    let server = Server::start(ServerConfig {
        hidden: vec![hidden],
        workers: 2,
        ..Default::default()
    })?;
    server.begin_session(session, hidden)?;
    let mut consumed = 0usize;
    for (ci, &len) in chunks.iter().enumerate() {
        let payload = utterance[consumed * hidden..(consumed + len) * hidden].to_vec();
        let resp = server.chunk(session, ci as u64, len, payload)?;
        // The step count is the client's eviction detector: a reset to 1
        // mid-stream would mean the carry was LRU-evicted and restarted.
        ensure!(
            resp.session_steps == Some(ci as u64 + 1),
            "carry restarted mid-stream"
        );
        consumed += len;
        println!(
            "chunk {ci}: {len} frames in {:.2} ms -> session carry updated ({consumed} frames total)",
            resp.latency_s * 1e3
        );
    }
    assert_eq!(consumed, t);
    let streamed = server
        .end_session(session)?
        .ok_or_else(|| anyhow!("session vanished"))?;
    ensure!(streamed.steps == chunks.len() as u64, "one carry per chunk");
    server.shutdown();

    // Reference: the whole utterance in one shot on the SAME artifact the
    // worker pins for sessions (`Manifest::session_seq` — each artifact
    // carries its own golden weights, so the comparison must bind the
    // same one). `run_prefix` stops exactly at frame 16, as the streamed
    // path did.
    let store = ArtifactStore::open_default()?;
    let entry = store
        .manifest
        .session_seq(hidden)
        .expect("seq artifacts exist")
        .clone();
    ensure!(entry.t >= t, "session bucket too small for the utterance");
    let exe = LstmExecutable::from_store_goldens(&store, &entry.name)?;
    let (b, d) = (entry.b, entry.d);
    let mut xs = vec![0.0f32; t * b * d];
    for step in 0..t {
        xs[step * b * d..step * b * d + d]
            .copy_from_slice(&utterance[step * hidden..(step + 1) * hidden]);
    }
    let (h0, c0) = exe.zero_state();
    let full = exe.run_prefix(&xs, t, &h0, &c0)?;

    let dh = max_abs_diff(&streamed.h, &full.h_t[..hidden]);
    let dc = max_abs_diff(&streamed.c, &full.c_t[..hidden]);
    println!("\nchunked-vs-full:  max|h| diff = {dh:.3e}, max|c| diff = {dc:.3e}");
    // "Bit-identical" means bit-identical: the doc claim above is the
    // contract tests/kernel_equivalence.rs enforces, so the e2e proof
    // uses the same comparison, not a tolerance.
    assert_bits_eq(&streamed.h, &full.h_t[..hidden], "chunked h carry");
    assert_bits_eq(&streamed.c, &full.c_t[..hidden], "chunked c carry");
    println!("streaming_asr OK (recurrent state carries across chunks exactly)");
    Ok(())
}
