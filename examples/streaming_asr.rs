//! Streaming inference: the online-ASR pattern the paper's intro
//! motivates — utterance frames arrive in chunks, and the recurrent
//! (h, c) state must persist across chunks. Drives the `cell` artifact
//! step-by-step through the `SessionStore` and proves the chunked result
//! is bit-identical to running the whole utterance through the `seq`
//! artifact in one shot (same weights, same schedule-invariance argument
//! as the Unfolded decomposition).
//!
//! Run: `make artifacts && cargo run --release --example streaming_asr`

use sharp::error::{ensure, Result};

use sharp::coordinator::SessionStore;
use sharp::runtime::{literal::max_abs_diff, ArtifactStore, LstmExecutable};
use sharp::util::rng::Rng;

fn main() -> Result<()> {
    let store = ArtifactStore::open_default()?;
    let hidden = 256usize;

    // One-step cell artifact for the streaming path...
    let cell = LstmExecutable::from_store_goldens(&store, "cell_h256_b1")?;
    // ...and the full-sequence artifact as the reference. They carry
    // different golden weights, so rebind the seq weights into the cell.
    let seq = LstmExecutable::from_store_goldens(&store, "seq_h256_t16_b1")?;
    let wmeta = |name: &str| {
        seq.entry
            .inputs
            .iter()
            .find(|i| i.name == name)
            .expect("weight input")
    };
    let cell = LstmExecutable::with_weights(
        &store,
        &cell.entry.name.clone(),
        store.golden(wmeta("wx"))?,
        store.golden(wmeta("wh"))?,
        store.golden(wmeta("b"))?,
    )?;

    // A 16-frame utterance, streamed in chunks of 3/5/8 frames.
    let t = 16usize;
    let mut rng = Rng::new(42);
    let utterance = rng.vec_f32(t * hidden, -1.0, 1.0);
    let chunks = [3usize, 5, 8];

    let mut sessions = SessionStore::new(hidden);
    let session_id = 7u64;
    let mut consumed = 0usize;
    for (ci, &len) in chunks.iter().enumerate() {
        let state = sessions.get_or_init(session_id);
        let mut h = state.h;
        let mut c = state.c;
        for step in 0..len {
            let frame = &utterance[(consumed + step) * hidden..(consumed + step + 1) * hidden];
            let out = cell.run(frame, &h, &c)?;
            h = out.h_t;
            c = out.c_t;
        }
        consumed += len;
        sessions.update(session_id, h, c);
        println!(
            "chunk {ci}: {len} frames -> session state updated ({} total)",
            consumed
        );
    }
    assert_eq!(consumed, t);
    let streamed = sessions.get_or_init(session_id);

    // Reference: whole utterance through the seq artifact in one shot.
    let (h0, c0) = seq.zero_state();
    let full = seq.run(&utterance, &h0, &c0)?;

    let dh = max_abs_diff(&streamed.h, &full.h_t);
    let dc = max_abs_diff(&streamed.c, &full.c_t);
    println!("\nchunked-vs-full:  max|h| diff = {dh:.3e}, max|c| diff = {dc:.3e}");
    ensure!(dh < 1e-4 && dc < 1e-4, "streaming state diverged");
    sessions.end(session_id);
    println!("streaming_asr OK (recurrent state carries across chunks exactly)");
    Ok(())
}
