//! Design-space exploration: run the paper's offline configuration search
//! (§6.2.2) for a user-supplied model and print the controller table the
//! reconfigurable hardware would be preloaded with — the artifact behind
//! Fig. 9's "there is not just one best configuration".
//!
//! Run: `cargo run --release --example design_space [hidden] [seq_len]`

use sharp::config::presets::{budget_label, K_RECONFIG, MAC_BUDGETS};
use sharp::config::{LstmConfig, SharpConfig};
use sharp::sched::ScheduleKind;
use sharp::sim::simulate;
use sharp::tile::explore::build_table;
use sharp::util::table::Table;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let hidden: u64 = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(340);
    let seq: u64 = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(25);

    println!("offline exploration for h={hidden}, T={seq} (K candidates {K_RECONFIG:?})\n");
    let mut t = Table::new("controller configuration table")
        .header(&["budget", "K_opt", "row_groups", "tile", "cycles", "vs K=32"]);
    for &macs in &MAC_BUDGETS {
        let base = SharpConfig::with_macs(macs);
        let model = LstmConfig::square(hidden).with_seq_len(seq);
        let table = build_table(&base, &[hidden], |cfg, _| {
            simulate(cfg, &model, ScheduleKind::Unfolded).cycles
        });
        let e = &table.entries[0];
        let naive = simulate(
            &base.clone().with_k(32),
            &model,
            ScheduleKind::Unfolded,
        )
        .cycles;
        let chosen = base.clone().with_k(e.k).with_row_groups(e.row_groups);
        t.row(&[
            budget_label(macs),
            format!("{}", e.k),
            format!("{}", e.row_groups),
            format!("{}x{}", chosen.tile_rows(), chosen.tile_cols()),
            format!("{}", e.cycles),
            format!("{:.2}x", naive as f64 / e.cycles as f64),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Each row is one entry the SHARP controller loads before a layer runs;\n\
         reconfiguration at runtime is just the table lookup + mux selects (§6.2.2)."
    );
}
