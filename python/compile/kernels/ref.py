"""Pure-jnp oracle for the LSTM computation (Fig. 2 of the paper).

No Pallas, no tiling — just the textbook recurrence.  Every kernel and every
model variant is checked against these functions at build time (pytest), and
the AOT goldens that the rust integration tests replay are generated from
the *kernel* path and cross-checked against this oracle first.

Gate order convention (shared repo-wide): the fused weight matrices have
column blocks ``[input | forget | cell(g) | output]``, each of width H.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def split_gates(pre, h: int):
    """Split a fused ``(..., 4H)`` pre-activation into (i, f, g, o)."""
    assert pre.shape[-1] == 4 * h, (pre.shape, h)
    return (
        pre[..., 0 * h : 1 * h],
        pre[..., 1 * h : 2 * h],
        pre[..., 2 * h : 3 * h],
        pre[..., 3 * h : 4 * h],
    )


def lstm_cell_ref(x, h, c, wx, wh, b):
    """One LSTM step. x:(B,D) h,c:(B,H) wx:(D,4H) wh:(H,4H) b:(4H,)."""
    hid = h.shape[-1]
    pre = x @ wx + h @ wh + b[None, :]
    i, f, g, o = split_gates(pre, hid)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def lstm_seq_ref(xs, h0, c0, wx, wh, b):
    """Naive sequential scan. xs:(T,B,D) -> (hs:(T,B,H), h_T, c_T)."""

    def step(carry, x_t):
        h, c = carry
        h_new, c_new = lstm_cell_ref(x_t, h, c, wx, wh, b)
        return (h_new, c_new), h_new

    (h_t, c_t), hs = jax.lax.scan(step, (h0, c0), xs)
    return hs, h_t, c_t


def lstm_stack_ref(xs, h0s, c0s, params):
    """Stacked layers: params = [(wx, wh, b), ...]; h0s/c0s: (L,B,H)."""
    hs = xs
    h_fin, c_fin = [], []
    for layer, (wx, wh, b) in enumerate(params):
        hs, h_t, c_t = lstm_seq_ref(hs, h0s[layer], c0s[layer], wx, wh, b)
        h_fin.append(h_t)
        c_fin.append(c_t)
    return hs, jnp.stack(h_fin), jnp.stack(c_fin)


# ----------------------------------------------------------------- GRU --
# Paper §8: "the same improvement can be achieved in other networks that
# have similar design, such as GRU". Gate order convention: [r | z | n]
# (reset, update, candidate), each of width H. We use the cuDNN-style
# "linear before reset" variant so the input MVM of every gate can be
# hoisted out of the recurrence exactly like the LSTM's Unfolded schedule:
#   r = sigmoid(x@Wr + h@Ur + br)
#   z = sigmoid(x@Wz + h@Uz + bz)
#   n = tanh(x@Wn + r * (h@Un) + bn)
#   h' = (1 - z) * n + z * h


def split_gru_gates(pre, h: int):
    """Split a fused ``(..., 3H)`` pre-activation into (r, z, n)."""
    assert pre.shape[-1] == 3 * h, (pre.shape, h)
    return pre[..., :h], pre[..., h : 2 * h], pre[..., 2 * h :]


def gru_cell_ref(x, h, wx, wh, b):
    """One GRU step. x:(B,D) h:(B,H) wx:(D,3H) wh:(H,3H) b:(3H,)."""
    hid = h.shape[-1]
    xpre = x @ wx + b[None, :]
    hpre = h @ wh
    xr, xz, xn = split_gru_gates(xpre, hid)
    hr, hz, hn = split_gru_gates(hpre, hid)
    r = jax.nn.sigmoid(xr + hr)
    z = jax.nn.sigmoid(xz + hz)
    n = jnp.tanh(xn + r * hn)
    return (1.0 - z) * n + z * h


def gru_seq_ref(xs, h0, wx, wh, b):
    """Naive GRU scan. xs:(T,B,D) -> (hs:(T,B,H), h_T)."""

    def step(h, x_t):
        h_new = gru_cell_ref(x_t, h, wx, wh, b)
        return h_new, h_new

    h_t, hs = jax.lax.scan(step, h0, xs)
    return hs, h_t
