"""L1 Pallas kernel: the GRU analogue of the Cell-Updater stage.

Paper §8 claims SHARP's improvements carry to "other networks that have
similar design, such as GRU"; this kernel is the GRU pointwise stage the
Cell Updater would run: given the input-side and hidden-side gate
pre-activations (the accumulator contents for the fused ``3H`` matrix),
it applies the r/z gating and emits the new hidden state. One fused
elementwise region, same structure as ``cell_update``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _gru_update_kernel(xr_ref, xz_ref, xn_ref, hr_ref, hz_ref, hn_ref, h_ref, h_out):
    r = jax.nn.sigmoid(xr_ref[...] + hr_ref[...])
    z = jax.nn.sigmoid(xz_ref[...] + hz_ref[...])
    n = jnp.tanh(xn_ref[...] + r * hn_ref[...])
    h_out[...] = (1.0 - z) * n + z * h_ref[...]


@functools.partial(jax.jit, static_argnames=("bb", "bh"))
def gru_update(xr, xz, xn, hr, hz, hn, h, *, bb: int = 8, bh: int = 128):
    """Fused GRU update over ``(B, H)`` gate slices; returns ``h_new``.

    ``x*`` are the input-side pre-activations (bias folded in), ``h*`` the
    hidden-side MVM results; gate order [r | z | n] (see ref.py).
    """
    b, hid = h.shape
    for a in (xr, xz, xn, hr, hz, hn):
        assert a.shape == (b, hid), f"gate shape {a.shape} != {(b, hid)}"
    bb = min(bb, _ceil_to(b, 1))
    bh = min(bh, _ceil_to(hid, 1))
    bp, hp = _ceil_to(b, bb), _ceil_to(hid, bh)
    pad = lambda a: jnp.pad(a, ((0, bp - b), (0, hp - hid)))
    spec = pl.BlockSpec((bb, bh), lambda i, j: (i, j))
    out = pl.pallas_call(
        _gru_update_kernel,
        grid=(bp // bb, hp // bh),
        in_specs=[spec] * 7,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bp, hp), jnp.float32),
        interpret=True,
    )(pad(xr), pad(xz), pad(xn), pad(hr), pad(hz), pad(hn), pad(h))
    return out[:b, :hid]
