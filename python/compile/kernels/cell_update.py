"""L1 Pallas kernel: the SHARP Cell-Updater stage.

Paper §4.3: once all four gates' MVM results are activated, the Cell Updater
(a) updates the cell state ``c_t = sigmoid(f)*c + sigmoid(i)*tanh(g)`` and
(b) produces the hidden output ``h_t = sigmoid(o)*tanh(c_t)``.  In hardware
this is an A-MFU plus pointwise fp16-multiply / fp32-add vector units that
emit K/4 hidden elements per cycle; here it is a single fused pointwise
Pallas kernel so XLA sees one elementwise region (no re-materialized gates).

The kernel takes *pre-activation* gate slices (the accumulator contents that
R-Add-Reduce hands to the A-MFU) so the sigmoid/tanh of the A-MFU live in
the same kernel — matching the paper's pipeline where activation and cell
update are fused stages of one flow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _cell_update_kernel(i_ref, f_ref, g_ref, o_ref, c_ref, h_out, c_out):
    i_g = jax.nn.sigmoid(i_ref[...])
    f_g = jax.nn.sigmoid(f_ref[...])
    g_g = jnp.tanh(g_ref[...])
    o_g = jax.nn.sigmoid(o_ref[...])
    c_new = f_g * c_ref[...] + i_g * g_g
    c_out[...] = c_new
    h_out[...] = o_g * jnp.tanh(c_new)


@functools.partial(jax.jit, static_argnames=("bb", "bh"))
def cell_update(i_pre, f_pre, g_pre, o_pre, c, *, bb: int = 8, bh: int = 128):
    """Fused LSTM cell update over ``(B, H)`` pre-activation gate slices.

    Returns ``(h_new, c_new)``.  Blocks over batch and hidden; padding rows
    carry zeros, and ``sigmoid(0)*tanh(0) == 0`` keeps padded cells inert.
    """
    b, h = c.shape
    for a in (i_pre, f_pre, g_pre, o_pre):
        assert a.shape == (b, h), f"gate shape {a.shape} != {(b, h)}"
    bb = min(bb, _ceil_to(b, 1))
    bh = min(bh, _ceil_to(h, 1))
    bp, hp = _ceil_to(b, bb), _ceil_to(h, bh)
    pad = lambda a: jnp.pad(a, ((0, bp - b), (0, hp - h)))
    grid = (bp // bb, hp // bh)
    spec = pl.BlockSpec((bb, bh), lambda i, j: (i, j))
    h_new, c_new = pl.pallas_call(
        _cell_update_kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct((bp, hp), jnp.float32),
            jax.ShapeDtypeStruct((bp, hp), jnp.float32),
        ),
        interpret=True,
    )(pad(i_pre), pad(f_pre), pad(g_pre), pad(o_pre), pad(c))
    return h_new[:b, :h], c_new[:b, :h]
