"""L1 Pallas kernel: the SHARP Compute-Unit's tiled matrix multiply.

The paper's Compute Unit is an array of ``N`` vector-scalar (VS) units of
width ``K`` that sweeps the fused 4-gate weight matrix in tiles (Fig. 6/7).
In the Pallas/TPU view the tile becomes a ``BlockSpec``: the block over the
contraction dimension plays the role of the VS width ``K``, while the block
over the output (gate) dimension corresponds to mapping VS units row- vs
column-wise.  ``tiled_matmul`` exposes those block shapes so the tests can
sweep them exactly the way Fig. 9 sweeps ``K``.

All kernels run with ``interpret=True`` so the lowered HLO executes on any
PJRT backend (the rust CPU client); real-TPU lowering would emit a Mosaic
custom-call instead.  Multiplication happens in the input dtype (fp16/bf16
in the paper, f32 here for oracle exactness) and accumulation is always f32
(``preferred_element_type``), mirroring the paper's fp16-mult/fp32-acc MACs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bk) x (bk, bf) tile-MAC; accumulates over the k grid dim.

    The ``k == 0`` init plus ``+=`` is the software analogue of the paper's
    accumulator bank that R-Add-Reduce updates as tiles stream through.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bf"))
def tiled_matmul(x, w, *, bm: int = 8, bk: int = 128, bf: int = 128):
    """``x @ w`` via the SHARP tile engine.

    Args:
      x: ``(M, D)`` activations (input or hidden vectors; M is batch*time).
      w: ``(D, F)`` weights (``F = 4H`` for the fused gate matrix).
      bm/bk/bf: tile shape. ``bk`` is the VS-unit width ``K``; ``bf`` is how
        many output columns one sweep covers (VS units mapped column-wise).

    Inputs whose dimensions are not multiples of the tile are zero-padded —
    this is precisely the MVM padding of paper §6.1.1; the rust simulator
    charges those wasted lanes, and `tile::reconfig` models removing them.
    """
    m, d = x.shape
    d2, f = w.shape
    assert d == d2, f"contraction mismatch {d} vs {d2}"
    bm = min(bm, _ceil_to(m, 1))
    mp, dp, fp = _ceil_to(m, bm), _ceil_to(d, bk), _ceil_to(f, bf)
    xp = jnp.pad(x, ((0, mp - m), (0, dp - d)))
    wp = jnp.pad(w, ((0, dp - d), (0, fp - f)))
    grid = (mp // bm, fp // bf, dp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bf), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, fp), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :f]


def gate_mvm(x, w_gates, b, *, bm: int = 8, bk: int = 128, bf: int = 128):
    """Fused 4-gate pre-activation: ``x @ W[D,4H] + b`` (one Compute-Unit pass).

    Gate order convention across the whole repo: columns of ``w_gates`` are
    ``[input | forget | cell(g) | output]`` blocks of width ``H`` each.
    """
    return tiled_matmul(x, w_gates, bm=bm, bk=bk, bf=bf) + b[None, :]
