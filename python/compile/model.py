"""L2: the JAX LSTM model, decomposed the way SHARP's *Unfolded* schedule does.

The paper's Unfolded scheduling (Fig. 8.d) rests on one observation: the
input-MVM ``x_t @ Wx`` of every timestep is independent of the recurrence, so
it can be hoisted out and overlapped with the serial cell/hidden chain.  The
L2 model is written in exactly that shape:

  * ``lstm_seq_unfolded`` computes the whole-sequence input GEMM up front
    (one big, MXU-friendly matmul through the L1 tile kernel), then a
    ``lax.scan`` carries only the hidden-MVM + cell-update critical path.
  * ``lstm_cell`` is the single-step function used by streaming sessions.

Both route their matmuls through ``kernels.mvm_tile`` (the Compute-Unit tile
engine) and the pointwise stage through ``kernels.cell_update`` (the
Cell-Updater), so the AOT artifact the rust runtime executes *is* the
paper's pipeline, not a generic LSTM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels.cell_update import cell_update
from compile.kernels.mvm_tile import gate_mvm, tiled_matmul
from compile.kernels.ref import split_gates


def lstm_cell(x, h, c, wx, wh, b, *, bm: int = 8, bk: int = 128, bf: int = 128):
    """One LSTM step through the Pallas tile + cell-update kernels.

    x:(B,D) h,c:(B,H) wx:(D,4H) wh:(H,4H) b:(4H,) -> (h_new, c_new).
    """
    hid = h.shape[-1]
    pre = gate_mvm(x, wx, b, bm=bm, bk=bk, bf=bf) + tiled_matmul(
        h, wh, bm=bm, bk=bk, bf=bf
    )
    i, f, g, o = split_gates(pre, hid)
    return cell_update(i, f, g, o, c, bb=bm, bh=min(bf, hid))


def lstm_seq_unfolded(
    xs, h0, c0, wx, wh, b, *, bm: int = 8, bk: int = 128, bf: int = 128
):
    """Full-sequence LSTM with the input GEMM hoisted (Unfolded schedule).

    xs:(T,B,D) h0,c0:(B,H) -> (hs:(T,B,H), h_T, c_T).

    The ``xs.reshape(T*B, D) @ wx`` below is the software twin of Fig. 8.d's
    "keep the MACs busy with step t+1's input MVM while step t's serial tail
    drains": all T input MVMs become one dependency-free matmul, and only
    ``h @ wh`` remains inside the scan (the true critical path).
    """
    t, bsz, d = xs.shape
    hid = h0.shape[-1]
    xin = gate_mvm(xs.reshape(t * bsz, d), wx, b, bm=bm, bk=bk, bf=bf)
    xin = xin.reshape(t, bsz, 4 * hid)

    def step(carry, xin_t):
        h, c = carry
        pre = xin_t + tiled_matmul(h, wh, bm=bm, bk=bk, bf=bf)
        i, f, g, o = split_gates(pre, hid)
        h_new, c_new = cell_update(i, f, g, o, c, bb=bm, bh=min(bf, hid))
        return (h_new, c_new), h_new

    (h_t, c_t), hs = jax.lax.scan(step, (h0, c0), xin)
    return hs, h_t, c_t


def lstm_stack_unfolded(xs, h0s, c0s, params, **tile):
    """Stacked uni-directional layers; params = [(wx, wh, b), ...]."""
    hs = xs
    h_fin, c_fin = [], []
    for layer, (wx, wh, b) in enumerate(params):
        hs, h_t, c_t = lstm_seq_unfolded(hs, h0s[layer], c0s[layer], wx, wh, b, **tile)
        h_fin.append(h_t)
        c_fin.append(c_t)
    return hs, jnp.stack(h_fin), jnp.stack(c_fin)


def make_cell_fn(*, bm=8, bk=128, bf=128):
    """Closure suitable for jax.jit/lower: (x, h, c, wx, wh, b) -> tuple."""

    def fn(x, h, c, wx, wh, b):
        h_new, c_new = lstm_cell(x, h, c, wx, wh, b, bm=bm, bk=bk, bf=bf)
        return (h_new, c_new)

    return fn


def make_seq_fn(*, bm=8, bk=128, bf=128):
    """Closure for the full-sequence unfolded model."""

    def fn(xs, h0, c0, wx, wh, b):
        hs, h_t, c_t = lstm_seq_unfolded(xs, h0, c0, wx, wh, b, bm=bm, bk=bk, bf=bf)
        return (hs, h_t, c_t)

    return fn


def init_params(key, d: int, h: int, scale: float = 0.2):
    """Deterministic small-magnitude LSTM params (for goldens and tests)."""
    k1, k2, k3 = jax.random.split(key, 3)
    wx = jax.random.uniform(k1, (d, 4 * h), jnp.float32, -scale, scale)
    wh = jax.random.uniform(k2, (h, 4 * h), jnp.float32, -scale, scale)
    b = jax.random.uniform(k3, (4 * h,), jnp.float32, -scale, scale)
    return wx, wh, b


# ----------------------------------------------------------------- GRU --
# Paper §8's generality claim ("the same improvement... such as GRU"):
# the same Unfolded decomposition applies — the fused 3-gate input MVM of
# every time step is recurrence-free and hoists out of the scan; only the
# hidden MVM + gated update remain on the critical path.

from compile.kernels.gru_update import gru_update
from compile.kernels.ref import split_gru_gates


def gru_cell(x, h, wx, wh, b, *, bm: int = 8, bk: int = 128, bf: int = 128):
    """One GRU step through the Pallas tile + update kernels.

    x:(B,D) h:(B,H) wx:(D,3H) wh:(H,3H) b:(3H,) -> h_new.
    """
    hid = h.shape[-1]
    xpre = gate_mvm(x, wx, b, bm=bm, bk=bk, bf=bf)
    hpre = tiled_matmul(h, wh, bm=bm, bk=bk, bf=bf)
    xr, xz, xn = split_gru_gates(xpre, hid)
    hr, hz, hn = split_gru_gates(hpre, hid)
    return gru_update(xr, xz, xn, hr, hz, hn, h, bb=bm, bh=min(bf, hid))


def gru_seq_unfolded(xs, h0, wx, wh, b, *, bm: int = 8, bk: int = 128, bf: int = 128):
    """Full-sequence GRU with the input GEMM hoisted (Unfolded schedule).

    xs:(T,B,D) h0:(B,H) -> (hs:(T,B,H), h_T).
    """
    t, bsz, d = xs.shape
    hid = h0.shape[-1]
    xin = gate_mvm(xs.reshape(t * bsz, d), wx, b, bm=bm, bk=bk, bf=bf)
    xin = xin.reshape(t, bsz, 3 * hid)

    def step(h, xin_t):
        hpre = tiled_matmul(h, wh, bm=bm, bk=bk, bf=bf)
        xr, xz, xn = split_gru_gates(xin_t, hid)
        hr, hz, hn = split_gru_gates(hpre, hid)
        h_new = gru_update(xr, xz, xn, hr, hz, hn, h, bb=bm, bh=min(bf, hid))
        return h_new, h_new

    h_t, hs = jax.lax.scan(step, h0, xin)
    return hs, h_t


def make_gru_cell_fn(*, bm=8, bk=128, bf=128):
    """Closure for jit/lower: (x, h, wx, wh, b) -> (h_new, h_new).

    The second element mirrors the first so cell artifacts expose the same
    2-tuple interface as LSTM cells (GRU carries no cell state); the rust
    runtime documents and relies on this uniformity.
    """

    def fn(x, h, wx, wh, b):
        h_new = gru_cell(x, h, wx, wh, b, bm=bm, bk=bk, bf=bf)
        return (h_new, h_new)

    return fn


def make_gru_seq_fn(*, bm=8, bk=128, bf=128):
    """Closure for the full-sequence GRU: returns (hs, h_T, h_T)."""

    def fn(xs, h0, wx, wh, b):
        hs, h_t = gru_seq_unfolded(xs, h0, wx, wh, b, bm=bm, bk=bk, bf=bf)
        return (hs, h_t, h_t)

    return fn


def init_gru_params(key, d: int, h: int, scale: float = 0.2):
    """Deterministic small-magnitude GRU params (gate order r|z|n)."""
    k1, k2, k3 = jax.random.split(key, 3)
    wx = jax.random.uniform(k1, (d, 3 * h), jnp.float32, -scale, scale)
    wh = jax.random.uniform(k2, (h, 3 * h), jnp.float32, -scale, scale)
    b = jax.random.uniform(k3, (3 * h,), jnp.float32, -scale, scale)
    return wx, wh, b
