"""AOT compiler: lower every model variant to HLO *text* + goldens.

Run once at build time (``make artifacts``); python never runs afterwards.

Interchange format is HLO text, NOT ``HloModuleProto.serialize()``: jax>=0.5
emits protos with 64-bit instruction ids that the rust side's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs into ``artifacts/``:
  * ``<name>.hlo.txt``      — the lowered computation (return_tuple=True)
  * ``<name>.<arg>.f32``    — golden inputs (little-endian f32, row-major)
  * ``<name>.out<i>.f32``   — golden outputs, produced by *running* the jitted
                              function on the golden inputs and cross-checked
                              against the pure-jnp oracle before writing
  * ``manifest.json``       — index the rust runtime loads

Variant set: per-step ``cell`` artifacts for streaming sessions and
full-sequence ``seq`` artifacts (unfolded schedule) for batch serving, over
the hidden sizes the serving example and the quickstart exercise.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import ref

# (name, kind, T, B, D, H) — kind: "cell" (one step) or "seq" (unfolded scan).
# Kept deliberately small-ish: every artifact is traced through interpret-mode
# pallas and compiled by the rust PJRT client in the integration tests.
VARIANTS = [
    ("cell_h64_b1", "cell", 1, 1, 64, 64),
    ("cell_h256_b1", "cell", 1, 1, 256, 256),
    ("cell_h256_b4", "cell", 1, 4, 256, 256),
    ("seq_h64_t8_b1", "seq", 8, 1, 64, 64),
    ("seq_h256_t16_b1", "seq", 16, 1, 256, 256),
    ("seq_h256_t16_b4", "seq", 16, 4, 256, 256),
    ("seq_h256_t32_b4", "seq", 32, 4, 256, 256),
    ("seq_h512_t16_b1", "seq", 16, 1, 512, 512),
    # GRU variants (paper §8's generality claim); same interface shape.
    ("gru_cell_h64_b1", "gru_cell", 1, 1, 64, 64),
    ("gru_seq_h256_t16_b4", "gru_seq", 16, 4, 256, 256),
]

# Tile (VS-unit) shape for the shipped artifacts — chosen by the same
# offline exploration the paper's controller table uses (§6.2.2), applied
# to THIS substrate (interpret-mode pallas on CPU-PJRT): sweeping block
# shapes on seq_h256_t16_b4 gave 63.2 ms @ (8,128,128) -> 14.7 ms @
# (32,256,512) -> 1.47 ms @ (64,256,1024), a 43x win by covering the fused
# gate matrix in one block per step. A fixed big tile then SLOWED the tiny
# h=64 variants ~2.7x (pure padding) — the paper's "no single best
# configuration" in miniature — so the tile adapts per variant, exactly
# like the controller table. See DESIGN.md §6 (performance notes).
TILE = dict(bm=64, bk=256, bf=1024)


def tile_for(t: int, b: int, d: int, h: int) -> dict:
    """Per-variant block shapes: cover the whole fused-gate matrix when it
    is small enough, never pad more than one block's worth of rows."""
    return dict(
        bm=min(64, max(8, t * b)),
        bk=min(256, max(32, d)),
        bf=min(1024, 4 * h),
    )


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dump(path: str, arr) -> dict:
    a = np.asarray(arr, dtype=np.float32)
    a.tofile(path)
    return {"file": os.path.basename(path), "shape": list(a.shape)}


def build_variant(name, kind, t, b, d, h, outdir, rtol=1e-5, atol=1e-5):
    key = jax.random.PRNGKey(hash(name) % (2**31))
    kx, kh, kc, kp = jax.random.split(key, 4)
    h0 = jax.random.uniform(kh, (b, h), jnp.float32, -1, 1)
    c0 = jax.random.uniform(kc, (b, h), jnp.float32, -1, 1)
    tile = tile_for(t, b, d, h)

    if kind == "cell":
        wx, wh, bias = model.init_params(kp, d, h)
        x = jax.random.uniform(kx, (b, d), jnp.float32, -1, 1)
        fn = model.make_cell_fn(**tile)
        args = (x, h0, c0, wx, wh, bias)
        argnames = ("x", "h0", "c0", "wx", "wh", "b")
        golden = jax.jit(fn)(*args)
        oracle = ref.lstm_cell_ref(x, h0, c0, wx, wh, bias)
    elif kind == "seq":
        wx, wh, bias = model.init_params(kp, d, h)
        xs = jax.random.uniform(kx, (t, b, d), jnp.float32, -1, 1)
        fn = model.make_seq_fn(**tile)
        args = (xs, h0, c0, wx, wh, bias)
        argnames = ("xs", "h0", "c0", "wx", "wh", "b")
        golden = jax.jit(fn)(*args)
        oracle = ref.lstm_seq_ref(xs, h0, c0, wx, wh, bias)
    elif kind == "gru_cell":
        # GRU carries no cell state; the fn returns (h', h') so cell
        # artifacts keep a uniform 2-tuple interface (see model.py).
        wx, wh, bias = model.init_gru_params(kp, d, h)
        x = jax.random.uniform(kx, (b, d), jnp.float32, -1, 1)
        fn = model.make_gru_cell_fn(**tile)
        args = (x, h0, wx, wh, bias)
        argnames = ("x", "h0", "wx", "wh", "b")
        golden = jax.jit(fn)(*args)
        href = ref.gru_cell_ref(x, h0, wx, wh, bias)
        oracle = (href, href)
    elif kind == "gru_seq":
        wx, wh, bias = model.init_gru_params(kp, d, h)
        xs = jax.random.uniform(kx, (t, b, d), jnp.float32, -1, 1)
        fn = model.make_gru_seq_fn(**tile)
        args = (xs, h0, wx, wh, bias)
        argnames = ("xs", "h0", "wx", "wh", "b")
        golden = jax.jit(fn)(*args)
        hs, h_t = ref.gru_seq_ref(xs, h0, wx, wh, bias)
        oracle = (hs, h_t, h_t)
    else:
        raise ValueError(f"unknown kind {kind}")

    # The kernel path must agree with the pure-jnp oracle before we bless it.
    for got, want in zip(golden, oracle):
        np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)

    hlo = to_hlo_text(jax.jit(fn).lower(*args))
    hlo_file = f"{name}.hlo.txt"
    with open(os.path.join(outdir, hlo_file), "w") as f:
        f.write(hlo)

    inputs = []
    for an, av in zip(argnames, args):
        meta = _dump(os.path.join(outdir, f"{name}.{an}.f32"), av)
        meta["name"] = an
        inputs.append(meta)
    outputs = [
        _dump(os.path.join(outdir, f"{name}.out{i}.f32"), g)
        for i, g in enumerate(golden)
    ]
    return {
        "name": name,
        "kind": kind,
        "hlo": hlo_file,
        "T": t,
        "B": b,
        "D": d,
        "H": h,
        "tile": tile,
        "inputs": inputs,
        "outputs": outputs,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated variant names")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    entries = []
    for name, kind, t, b, d, h in VARIANTS:
        if only and name not in only:
            continue
        print(f"[aot] {name} (kind={kind} T={t} B={b} D={d} H={h})", flush=True)
        entries.append(build_variant(name, kind, t, b, d, h, args.outdir))

    manifest = {"version": 1, "gate_order": "ifgo", "artifacts": entries}
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(entries)} artifacts to {args.outdir}")


if __name__ == "__main__":
    sys.exit(main())
