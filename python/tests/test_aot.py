"""AOT path tests: HLO-text lowering round-trips and the manifest/golden
contract the rust runtime relies on. Uses a tiny variant so the full
lower-dump-verify cycle runs in CI time.
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model


def test_hlo_text_lowering_is_parseable_text(tmp_path):
    """The interchange format is HLO text with an ENTRY computation."""
    fn = model.make_cell_fn(bm=8, bk=32, bf=32)
    spec = lambda *s: jax.ShapeDtypeStruct(s, jax.numpy.float32)
    lowered = jax.jit(fn).lower(
        spec(1, 8), spec(1, 8), spec(1, 8), spec(8, 32), spec(8, 32), spec(32,)
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text
    # No serialized-proto artifacts: this is plain text.
    assert text.isprintable() or "\n" in text


def test_build_variant_writes_consistent_bundle(tmp_path):
    entry = aot.build_variant("tiny_cell", "cell", 1, 1, 8, 8, str(tmp_path))
    # Files exist and shapes match the dumped bytes.
    assert (tmp_path / entry["hlo"]).exists()
    for meta in entry["inputs"] + entry["outputs"]:
        data = np.fromfile(tmp_path / meta["file"], dtype=np.float32)
        assert data.size == int(np.prod(meta["shape"])), meta
    # Golden outputs reproduce when re-running the jitted function.
    names = [i["name"] for i in entry["inputs"]]
    assert names == ["x", "h0", "c0", "wx", "wh", "b"]


def test_build_variant_seq_kind(tmp_path):
    entry = aot.build_variant("tiny_seq", "seq", 3, 2, 8, 8, str(tmp_path))
    assert entry["kind"] == "seq"
    assert [i["name"] for i in entry["inputs"]][0] == "xs"
    assert len(entry["outputs"]) == 3  # hs, h_T, c_T
    hs_shape = entry["outputs"][0]["shape"]
    assert hs_shape == [3, 2, 8]


def test_manifest_contract(tmp_path):
    """The manifest the rust json parser consumes: structure + gate order."""
    entry = aot.build_variant("tiny_cell2", "cell", 1, 1, 8, 8, str(tmp_path))
    manifest = {"version": 1, "gate_order": "ifgo", "artifacts": [entry]}
    path = tmp_path / "manifest.json"
    with open(path, "w") as f:
        json.dump(manifest, f)
    with open(path) as f:
        back = json.load(f)
    assert back["gate_order"] == "ifgo"
    art = back["artifacts"][0]
    for key in ("name", "kind", "hlo", "T", "B", "D", "H", "inputs", "outputs"):
        assert key in art, key


def test_variant_table_is_well_formed():
    names = [v[0] for v in aot.VARIANTS]
    assert len(names) == len(set(names)), "duplicate variant names"
    for name, kind, t, b, d, h in aot.VARIANTS:
        assert kind in ("cell", "seq", "gru_cell", "gru_seq")
        if kind.endswith("cell"):
            assert t == 1, f"{name}: cell variants are single-step"
        assert b >= 1 and d >= 1 and h >= 1
        assert f"h{h}" in name


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_shipped_artifacts_goldens_reproduce():
    """Re-execute one shipped artifact's function and match its goldens."""
    art_dir = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(art_dir, "manifest.json")) as f:
        manifest = json.load(f)
    entry = next(e for e in manifest["artifacts"] if e["kind"] == "cell")
    load = lambda meta: np.fromfile(
        os.path.join(art_dir, meta["file"]), dtype=np.float32
    ).reshape(meta["shape"])
    ins = {m["name"]: load(m) for m in entry["inputs"]}
    fn = model.make_cell_fn(**entry.get("tile", aot.TILE))
    got = jax.jit(fn)(ins["x"], ins["h0"], ins["c0"], ins["wx"], ins["wh"], ins["b"])
    for g, meta in zip(got, entry["outputs"]):
        np.testing.assert_allclose(g, load(meta), rtol=1e-6, atol=1e-6)
