"""L1 kernel correctness: Pallas tile MVM + cell update vs the pure-jnp
oracle. Hypothesis sweeps shapes, tile (block) configurations and dtypes —
the software twin of the paper's Fig. 9 K-width sweep, with the oracle as
ground truth. This is the CORE correctness signal of the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cell_update import cell_update
from compile.kernels.mvm_tile import gate_mvm, tiled_matmul
from compile.kernels.ref import lstm_cell_ref, split_gates

jax.config.update("jax_enable_x64", False)

# Keep hypothesis runs modest: interpret-mode pallas re-traces per shape.
COMMON = dict(max_examples=12, deadline=None)


def rand(key, shape, lo=-1.0, hi=1.0, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, lo, hi)


# ----------------------------------------------------------------- MVM --


@settings(**COMMON)
@given(
    m=st.integers(1, 9),
    d=st.integers(1, 80),
    f=st.integers(1, 96),
    bk=st.sampled_from([8, 32, 128]),
    bf=st.sampled_from([16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tiled_matmul_matches_jnp(m, d, f, bk, bf, seed):
    """Any (ragged) shape x any tile config == plain jnp matmul."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = rand(k1, (m, d))
    w = rand(k2, (d, f))
    got = tiled_matmul(x, w, bm=8, bk=bk, bf=bf)
    np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-5)


@settings(**COMMON)
@given(
    h=st.sampled_from([3, 16, 40, 64]),
    b=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_gate_mvm_fused_bias(h, b, seed):
    """The fused 4-gate pre-activation includes the bias broadcast."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(k1, (b, h))
    w = rand(k2, (h, 4 * h))
    bias = rand(k3, (4 * h,))
    got = gate_mvm(x, w, bias, bm=8, bk=32, bf=32)
    np.testing.assert_allclose(got, x @ w + bias[None, :], rtol=1e-5, atol=1e-5)


def test_matmul_rejects_contraction_mismatch():
    with pytest.raises(AssertionError):
        tiled_matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))


def test_matmul_accumulates_over_k_grid():
    """D much larger than bk forces multi-step accumulator revisits."""
    key = jax.random.PRNGKey(0)
    x = rand(key, (4, 1000))
    w = rand(jax.random.PRNGKey(1), (1000, 64))
    got = tiled_matmul(x, w, bm=4, bk=128, bf=64)
    np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)


def test_matmul_bf16_inputs_accumulate_f32():
    """The paper's fp16-mult/fp32-acc: low-precision in, f32 out."""
    key = jax.random.PRNGKey(7)
    x = rand(key, (4, 64)).astype(jnp.bfloat16)
    w = rand(jax.random.PRNGKey(8), (64, 32)).astype(jnp.bfloat16)
    got = tiled_matmul(x, w, bm=4, bk=32, bf=32)
    assert got.dtype == jnp.float32
    want = x.astype(jnp.float32) @ w.astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


# --------------------------------------------------------- cell update --


@settings(**COMMON)
@given(
    b=st.integers(1, 6),
    h=st.sampled_from([1, 5, 32, 100, 128]),
    bh=st.sampled_from([16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cell_update_matches_oracle(b, h, bh, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    pre = [rand(k, (b, h), -3.0, 3.0) for k in keys[:4]]
    c = rand(keys[4], (b, h))
    h_new, c_new = cell_update(*pre, c, bb=8, bh=bh)
    i, f, g, o = pre
    c_want = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_want = jax.nn.sigmoid(o) * jnp.tanh(c_want)
    np.testing.assert_allclose(c_new, c_want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h_new, h_want, rtol=1e-5, atol=1e-6)


def test_cell_update_padding_lanes_inert():
    """Zero-padded cells must not contaminate real outputs (ragged H)."""
    b, h = 2, 33  # pads to (8, 128) internally
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    pre = [rand(k, (b, h)) for k in keys[:4]]
    c = rand(keys[4], (b, h))
    h_new, c_new = cell_update(*pre, c, bb=8, bh=128)
    assert h_new.shape == (b, h)
    assert c_new.shape == (b, h)
    assert bool(jnp.all(jnp.isfinite(h_new)))


def test_cell_update_shape_mismatch_rejected():
    z = jnp.zeros((2, 4))
    with pytest.raises(AssertionError):
        cell_update(z, z, z, jnp.zeros((2, 5)), z)


# -------------------------------------------------- full cell via kernels --


@settings(**COMMON)
@given(
    h=st.sampled_from([8, 40, 64]),
    b=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_lstm_cell_matches_ref(h, b, seed):
    """Compose both kernels into one LSTM step == the textbook cell."""
    from compile.model import lstm_cell

    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = rand(keys[0], (b, h))
    h0 = rand(keys[1], (b, h))
    c0 = rand(keys[2], (b, h))
    wx = rand(keys[3], (h, 4 * h), -0.3, 0.3)
    wh = rand(keys[4], (h, 4 * h), -0.3, 0.3)
    bias = rand(keys[5], (4 * h,), -0.3, 0.3)
    got_h, got_c = lstm_cell(x, h0, c0, wx, wh, bias, bm=8, bk=32, bf=32)
    want_h, want_c = lstm_cell_ref(x, h0, c0, wx, wh, bias)
    np.testing.assert_allclose(got_h, want_h, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_c, want_c, rtol=1e-5, atol=1e-5)


def test_split_gates_order_convention():
    """ifgo column-block order — the contract the rust side relies on."""
    h = 2
    pre = jnp.arange(8.0)[None, :]  # one row: [0..7]
    i, f, g, o = split_gates(pre, h)
    assert i.tolist() == [[0.0, 1.0]]
    assert f.tolist() == [[2.0, 3.0]]
    assert g.tolist() == [[4.0, 5.0]]
    assert o.tolist() == [[6.0, 7.0]]
