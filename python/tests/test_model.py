"""L2 model correctness: the Unfolded decomposition must be numerically
identical to the naive recurrent scan — the schedule reorders work, it
never changes the math (paper §5's core claim, checked to float tolerance).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

COMMON = dict(max_examples=8, deadline=None)


def params(seed, d, h):
    return model.init_params(jax.random.PRNGKey(seed), d, h)


def states(seed, b, h):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
    return (
        jax.random.uniform(k1, (b, h), jnp.float32, -1, 1),
        jax.random.uniform(k2, (b, h), jnp.float32, -1, 1),
    )


@settings(**COMMON)
@given(
    t=st.integers(1, 12),
    b=st.integers(1, 3),
    h=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 10_000),
)
def test_unfolded_equals_naive_scan(t, b, h, seed):
    """Hoisting the input GEMM out of the scan changes nothing numerically."""
    wx, wh, bias = params(seed, h, h)
    h0, c0 = states(seed, b, h)
    xs = jax.random.uniform(jax.random.PRNGKey(seed + 2), (t, b, h), jnp.float32, -1, 1)
    hs_u, ht_u, ct_u = model.lstm_seq_unfolded(xs, h0, c0, wx, wh, bias, bm=8, bk=32, bf=32)
    hs_r, ht_r, ct_r = ref.lstm_seq_ref(xs, h0, c0, wx, wh, bias)
    np.testing.assert_allclose(hs_u, hs_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ht_u, ht_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ct_u, ct_r, rtol=1e-5, atol=1e-5)


def test_hidden_sequence_last_step_is_final_state():
    wx, wh, bias = params(7, 16, 16)
    h0, c0 = states(7, 2, 16)
    xs = jax.random.uniform(jax.random.PRNGKey(9), (5, 2, 16), jnp.float32, -1, 1)
    hs, h_t, _ = model.lstm_seq_unfolded(xs, h0, c0, wx, wh, bias, bm=8, bk=32, bf=32)
    np.testing.assert_allclose(hs[-1], h_t, rtol=0, atol=0)


def test_stacked_layers_match_ref():
    d = h = 16
    layers = [params(s, d, h) for s in range(3)]
    h0s = jnp.zeros((3, 2, h))
    c0s = jnp.zeros((3, 2, h))
    xs = jax.random.uniform(jax.random.PRNGKey(1), (4, 2, d), jnp.float32, -1, 1)
    got = model.lstm_stack_unfolded(xs, h0s, c0s, layers, bm=8, bk=32, bf=32)
    want = ref.lstm_stack_ref(xs, h0s, c0s, layers)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)


def test_cell_fn_closure_matches_direct_call():
    wx, wh, bias = params(3, 32, 32)
    h0, c0 = states(3, 1, 32)
    x = jax.random.uniform(jax.random.PRNGKey(4), (1, 32), jnp.float32, -1, 1)
    fn = model.make_cell_fn(bm=8, bk=32, bf=32)
    got = fn(x, h0, c0, wx, wh, bias)
    want = model.lstm_cell(x, h0, c0, wx, wh, bias, bm=8, bk=32, bf=32)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=0, atol=0)


def test_long_sequence_stays_bounded():
    """LSTM gating keeps activations in (-1, 1) over long horizons."""
    wx, wh, bias = params(11, 24, 24)
    h0 = jnp.zeros((1, 24))
    c0 = jnp.zeros((1, 24))
    xs = jax.random.uniform(jax.random.PRNGKey(12), (64, 1, 24), jnp.float32, -1, 1)
    hs, _, _ = model.lstm_seq_unfolded(xs, h0, c0, wx, wh, bias, bm=8, bk=32, bf=32)
    assert bool(jnp.all(jnp.abs(hs) < 1.0))
    assert bool(jnp.all(jnp.isfinite(hs)))


def test_init_params_deterministic_and_shaped():
    a = model.init_params(jax.random.PRNGKey(5), 16, 8)
    b = model.init_params(jax.random.PRNGKey(5), 16, 8)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    wx, wh, bias = a
    assert wx.shape == (16, 32)
    assert wh.shape == (8, 32)
    assert bias.shape == (32,)
