"""GRU kernel/model correctness (paper §8's generality claim): the Pallas
GRU update kernel and the unfolded GRU sequence against the pure-jnp
oracle, hypothesis-swept like the LSTM path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.gru_update import gru_update

COMMON = dict(max_examples=10, deadline=None)


def rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


@settings(**COMMON)
@given(
    b=st.integers(1, 5),
    h=st.sampled_from([1, 7, 32, 100]),
    bh=st.sampled_from([16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gru_update_kernel_matches_oracle(b, h, bh, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 7)
    xr, xz, xn, hr, hz, hn = (rand(k, (b, h), -3.0, 3.0) for k in keys[:6])
    h0 = rand(keys[6], (b, h))
    got = gru_update(xr, xz, xn, hr, hz, hn, h0, bb=8, bh=bh)
    r = jax.nn.sigmoid(xr + hr)
    z = jax.nn.sigmoid(xz + hz)
    n = jnp.tanh(xn + r * hn)
    want = (1.0 - z) * n + z * h0
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(**COMMON)
@given(
    h=st.sampled_from([8, 24, 64]),
    b=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
def test_gru_cell_matches_ref(h, b, seed):
    wx, wh, bias = model.init_gru_params(jax.random.PRNGKey(seed), h, h)
    x = rand(jax.random.PRNGKey(seed + 1), (b, h))
    h0 = rand(jax.random.PRNGKey(seed + 2), (b, h))
    got = model.gru_cell(x, h0, wx, wh, bias, bm=8, bk=32, bf=32)
    want = ref.gru_cell_ref(x, h0, wx, wh, bias)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**COMMON)
@given(
    t=st.integers(1, 10),
    b=st.integers(1, 3),
    h=st.sampled_from([8, 32]),
    seed=st.integers(0, 10_000),
)
def test_gru_unfolded_equals_naive_scan(t, b, h, seed):
    """The Unfolded decomposition generalizes to GRU (paper §8)."""
    wx, wh, bias = model.init_gru_params(jax.random.PRNGKey(seed), h, h)
    h0 = rand(jax.random.PRNGKey(seed + 1), (b, h))
    xs = rand(jax.random.PRNGKey(seed + 2), (t, b, h))
    hs_u, ht_u = model.gru_seq_unfolded(xs, h0, wx, wh, bias, bm=8, bk=32, bf=32)
    hs_r, ht_r = ref.gru_seq_ref(xs, h0, wx, wh, bias)
    np.testing.assert_allclose(hs_u, hs_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ht_u, ht_r, rtol=1e-5, atol=1e-5)


def test_gru_update_gate_semantics():
    """z=1 keeps the old state; z=0 replaces it with the candidate."""
    b, h = 1, 8
    big = jnp.full((b, h), 30.0)  # sigmoid ~ 1
    small = jnp.full((b, h), -30.0)  # sigmoid ~ 0
    zeros = jnp.zeros((b, h))
    h0 = jnp.linspace(-0.5, 0.5, h)[None, :]
    # z ~ 1: h' == h0.
    keep = gru_update(zeros, big, zeros, zeros, zeros, zeros, h0)
    np.testing.assert_allclose(keep, h0, atol=1e-6)
    # z ~ 0, n = tanh(xn): h' == tanh(xn).
    xn = jnp.full((b, h), 0.7)
    replace = gru_update(zeros, small, xn, zeros, zeros, zeros, h0)
    np.testing.assert_allclose(replace, jnp.tanh(xn), atol=1e-6)


def test_gru_seq_fn_tuple_convention():
    """make_gru_seq_fn returns (hs, h_T, h_T) — the uniform interface the
    rust runtime relies on (GRU has no cell state)."""
    wx, wh, bias = model.init_gru_params(jax.random.PRNGKey(0), 8, 8)
    xs = rand(jax.random.PRNGKey(1), (3, 2, 8))
    h0 = jnp.zeros((2, 8))
    out = model.make_gru_seq_fn(bm=8, bk=32, bf=32)(xs, h0, wx, wh, bias)
    assert len(out) == 3
    np.testing.assert_array_equal(out[1], out[2])
    np.testing.assert_array_equal(out[0][-1], out[1])


def test_gru_update_shape_mismatch_rejected():
    z = jnp.zeros((2, 4))
    with pytest.raises(AssertionError):
        gru_update(z, z, z, z, z, jnp.zeros((2, 5)), z)
