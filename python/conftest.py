"""Make `pytest python/tests/` work from the repo root: the `compile`
package lives in this directory, so put it on sys.path regardless of the
invocation cwd."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
